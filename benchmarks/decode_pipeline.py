"""Streaming end-cloud decode benchmark: pipelined vs serial step time.

Runs the same decode workload through

  * the single-tier continuous-batching ``ServingEngine`` (baseline), and
  * the streaming ``EndCloudServingEngine`` at the route-aware split, with
    the boundary double-buffered across two micro-batch groups,

and reports steady-state step times.  Stage compute times are measured on
this host; link times are modeled from the metered boundary bytes at the
configured bandwidth; the pipelined schedule is the resource-occupancy
timeline (same queueing model as ``repro.sim.simulator``).  The headline
check is the PO-ECC pipelining claim:

    pipelined_step_s  <  serial_step_s = t_end + t_comm + t_cloud
    pipelined_step_s  ->  max(t_end, t_comm, t_cloud)   (steady state)

A second phase degrades the end device's state mid-run to exercise dynamic
replanning (paper fig. 7's changing-load scenario): the engine re-splits
params and moves KV *pages* between the tier pools at a request-safe
boundary and keeps decoding.  (A pure bandwidth change with the codec off
does not move the split here: with the boundary shipped at every split,
wire cost is split-independent, and the replan hysteresis correctly refuses
a drain that buys nothing.)

A third phase admits one long prompt into a busy engine and asserts the
chunked-prefill claim: in-flight decode groups keep emitting tokens on
every tick of the prompt's prefill (admission is a pipeline stage streaming
through the same StageTimeline resources as decode, not a stop-the-world
event), and the engine compiles one trace per chunk/group shape, never one
per prompt length.

A separate MoE scenario (``run_expert``) exercises the paged expert-weight
pool under device-state degradation: the end device's memory budget halves
mid-run (the slab capacity follows it — residents are EVICTED at a safe
point, not merely routing-masked), then recovers (the re-grown expert set
is PREFETCHED, slab bytes booked on the same link timeline as boundary
traffic, overlapped with decode).  Asserted: expert hit rate above
threshold after each warmup, prefetch bytes actually booked on the link
resource, pipelined step < serial sum throughout, and per-step end-tier
expert HBM bytes <= 1/2 of the dense [E, d, f] sweep at the paper's 40%
selection cap.

Paged-KV memory accounting (``kv_pages_in_use`` / ``kv_bytes_peak`` /
``kv_utilization``) is reported alongside the dense ``max_batch x max_len``
equivalent, and the same live sample point checks the fused paged-attention
claim: per-decode-step attention KV bytes scale with *mapped pages*
(``attn_bytes_paged_step``), not slots x ring — the skewed batch must move
< 1/2 of the dense-gather bytes (``attn_bytes_dense_step``), asserted.

A final quantization phase (``run_quant``) replays the workload with the
int8 second-stage codecs on (KV pages, boundary payloads, expert slabs)
and asserts each metered byte stream lands at <= 0.55x its f32-path
counterpart, page/slab capacity >= 1.9x, and greedy decode matches the
unquantized engine within the documented tolerance.

    PYTHONPATH=src python -m benchmarks.decode_pipeline [--out BENCH_decode_pipeline.json]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.hardware import DeviceProfile, DeviceState
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.stream import EndCloudServingEngine

# Device profiles calibrated to smoke-model scale (the paper-testbed profiles
# paired with a ~100k-param smoke model put every split in the all-cloud
# corner; these keep the planner in the interior regime the paper studies:
# end ~3x weaker than cloud, link fast enough that an interior split wins
# until the mid-run bandwidth drop).
END_SIM = DeviceProfile("end-sim", peak_gflops=2.0, mem_gb=8.0,
                        mem_bw_gbs=50.0, net_gbps=2.0)
CLOUD_SIM = DeviceProfile("cloud-sim", peak_gflops=6.0, mem_gb=80.0,
                          mem_bw_gbs=500.0, net_gbps=2.0)


def _requests(n: int, max_new_tokens: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, 500, size=int(rng.integers(8, 24))).astype(np.int32),
                max_new_tokens=max_new_tokens)
        for i in range(n)
    ]


def run(
    *,
    arch: str = "tinyllama-1.1b",
    num_layers: int = 4,
    n_requests: int = 12,
    max_new_tokens: int = 24,
    max_batch: int = 8,
    compression_rank: int = 0,
    seed: int = 0,
) -> Dict:
    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    # -- baseline: single-tier continuous batching ---------------------------
    base = ServingEngine(model, params, max_batch=max_batch, max_len=128)
    for r in _requests(n_requests, max_new_tokens, seed):
        base.submit(r)
    t0 = time.perf_counter()
    base_done = base.run()
    base_wall = time.perf_counter() - t0
    base_tokens = sum(len(r.generated) for r in base_done)

    # -- streaming two-tier pipeline -----------------------------------------
    eng = EndCloudServingEngine(
        model, params,
        end_profile=END_SIM,
        cloud_profile=CLOUD_SIM,
        max_batch=max_batch, max_len=128,
        compression_rank=compression_rank,
    )
    reqs = _requests(n_requests, max_new_tokens, seed)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    stream_tokens = sum(len(r.generated) for r in done)
    m = eng.metrics()

    # -- dynamic load: the end device gets busy mid-run (fig. 7 scenario);
    # -- the replanner offloads blocks to the cloud at a safe point ----------
    replan_reqs = _requests(n_requests, max_new_tokens, seed + 1)
    for r in replan_reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.update_device_state(DeviceState(cpu_free=0.05, power_free=0.1))
    eng.run()
    m2 = eng.metrics()

    # -- chunked prefill: a long prompt admitted mid-stream must not stall
    # -- the in-flight decode groups (no stop-the-world admission).  One
    # -- slot is left free for the long prompt; every other slot decodes a
    # -- long generation, and must keep emitting on every prefill tick. ----
    rng = np.random.default_rng(seed + 2)
    for r in _requests(eng.request_capacity - 1, 64, seed + 3):
        eng.submit(r)
    for _ in range(4):
        eng.step()
    long_prompt = rng.integers(0, 500, size=96).astype(np.int32)
    long_req = Request(10_000, long_prompt, max_new_tokens=4)
    eng.submit(long_req)
    chunks_before = eng.metrics()["prefill_chunks"]
    stalled_ticks = prefill_ticks = 0
    while any(j.req is long_req for j in eng._jobs.values()) or eng.waiting:
        live = [r for r in eng.slots if r is not None]
        before = sum(len(r.generated) for r in live) + sum(
            len(r.generated) for r in eng.finished
        )
        eng.step()
        live = [r for r in eng.slots if r is not None]
        after = sum(len(r.generated) for r in live) + sum(
            len(r.generated) for r in eng.finished
        )
        prefill_ticks += 1
        if after == before:
            stalled_ticks += 1
    # sample KV occupancy while the batch is still live (after run() every
    # page is freed, so in-use/utilization would always read zero)
    kv_mid = eng.kv_metrics()
    # attention-bytes check, sampled at the same live point: the batch is
    # skewed (one 96-token prompt among short decodes), so the fused paged
    # sweep — which reads only *mapped* pages — must move well under half
    # of what the dense gather swept (slots x full ring, every step)
    assert 0 < kv_mid["attn_bytes_paged_step"] < kv_mid["attn_bytes_dense_step"], (
        kv_mid
    )
    assert kv_mid["attn_bytes_paged_step"] <= kv_mid["attn_bytes_dense_step"] / 2, (
        "paged attention must move < 1/2 the dense-gather KV bytes on a "
        f"skewed-length batch: {kv_mid}"
    )
    eng.run()
    m3 = eng.metrics()
    prefill_chunks = m3["prefill_chunks"] - chunks_before
    assert stalled_ticks == 0, (
        f"chunked prefill stalled decode for {stalled_ticks}/{prefill_ticks} "
        "ticks — admission must be a pipeline stage, not a stop-the-world event"
    )
    assert prefill_chunks >= len(long_prompt) // eng.prefill_chunk, (
        prefill_chunks, len(long_prompt), eng.prefill_chunk
    )
    # prefill chunks are StageTimeline occupancy on the same resources
    assert eng._prefill_busy["end"] > 0 and eng._prefill_busy["cloud"] > 0
    # compiled stage traces are bounded by chunk/group shapes (per stage-fn
    # rebuild), never by the number of distinct prompt lengths served
    traces = eng.stage_trace_counts()
    n_builds = eng._build_gen
    assert all(c <= n_builds for c in traces.values()), (traces, n_builds)

    row = {
        "arch": cfg.name,
        "block_repeat": cfg.block_repeat,
        "split": m["split"],
        "compressed": m["compressed"],
        "n_groups": m["n_groups"],
        "tokens_baseline": base_tokens,
        "tokens_streamed": stream_tokens,
        "baseline_wall_s": round(base_wall, 4),
        "stream_wall_s": round(wall, 4),
        "mean_t_end_s": round(m["mean_t_end_s"], 6),
        "mean_t_comm_s": round(m["mean_t_comm_s"], 6),
        "mean_t_cloud_s": round(m["mean_t_cloud_s"], 6),
        "serial_step_s": round(m["serial_step_s"], 6),
        "pipelined_step_s": round(m["pipelined_step_s"], 6),
        "max_stage_s": round(
            max(m["mean_t_end_s"], m["mean_t_comm_s"], m["mean_t_cloud_s"]), 6
        ),
        "plan_est_step_s": round(m["plan_est_step_s"], 6),
        "boundary_bytes_up": m["bytes_up"],
        "overlap_gain": round(m["serial_step_s"] / max(m["pipelined_step_s"], 1e-12), 3),
        "replan_events": m2["replan_events"],
        "split_after_load_spike": m2["split"],
        # paged KV-memory accounting (vs the dense max_batch x max_len
        # layout); in-use/utilization sampled mid-run with the batch live
        "kv_pages_in_use": kv_mid["kv_pages_in_use"],
        "kv_pages_capacity": kv_mid["kv_pages_capacity"],
        "kv_utilization": round(kv_mid["kv_utilization"], 4),
        "kv_bytes_peak": m3["kv_bytes_peak"],
        "kv_bytes_dense_equiv": m3["kv_bytes_dense_equiv"],
        # per-decode-step attention KV traffic at the skewed-batch sample
        # point: the fused paged kernel reads mapped pages only, the dense
        # gather it replaced swept slots x ring every step
        "attn_bytes_paged_step": kv_mid["attn_bytes_paged_step"],
        "attn_bytes_dense_step": kv_mid["attn_bytes_dense_step"],
        "attn_bytes_ratio": round(
            kv_mid["attn_bytes_paged_step"]
            / max(kv_mid["attn_bytes_dense_step"], 1), 4
        ),
        # chunked-prefill pipeline accounting
        "prefill_chunks": m3["prefill_chunks"],
        "long_prompt_prefill_ticks": prefill_ticks,
        "long_prompt_stalled_ticks": stalled_ticks,
        "stage_trace_counts": traces,
    }
    print(
        f"[decode_pipeline] split={row['split']}/{cfg.block_repeat} "
        f"serial={row['serial_step_s']*1e3:.2f}ms "
        f"pipelined={row['pipelined_step_s']*1e3:.2f}ms "
        f"(max stage {row['max_stage_s']*1e3:.2f}ms, x{row['overlap_gain']} overlap) "
        f"replans={row['replan_events']} -> split {row['split_after_load_spike']}",
        flush=True,
    )
    print(
        f"[decode_pipeline] kv peak {row['kv_bytes_peak']/1024:.1f}KiB "
        f"vs dense {row['kv_bytes_dense_equiv']/1024:.1f}KiB; "
        f"long-prompt prefill: {prefill_ticks} ticks, {stalled_ticks} stalled, "
        f"traces {traces}",
        flush=True,
    )
    print(
        f"[decode_pipeline] attention sweep "
        f"{row['attn_bytes_paged_step']/1024:.1f}KiB/step (mapped pages) "
        f"vs {row['attn_bytes_dense_step']/1024:.1f}KiB/step dense gather "
        f"(x{row['attn_bytes_ratio']} of dense on the skewed batch)",
        flush=True,
    )
    assert row["pipelined_step_s"] < row["serial_step_s"], (
        "pipelined decode must beat the serial sum of stage times"
    )
    return row


def run_expert(
    *,
    arch: str = "llama4-scout-17b-16e",
    num_layers: int = 4,
    n_requests: int = 8,
    max_new_tokens: int = 16,
    max_batch: int = 4,
    seed: int = 0,
) -> Dict:
    """Paged expert-weight pool under device-state degradation."""
    from repro.core.expertpool import expert_slab_bytes

    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # split 1 is the planner's optimum at the END_SIM/CLOUD_SIM compute
    # ratio (s/2 = (R-s)/6 at R=4), so mid-run replan rechecks keep the
    # pinned split and the device-state changes exercise ONLY the expert
    # pool, not a tier re-split
    split = 1
    n_moe_pos = sum(1 for s in cfg.layer_pattern if s.moe)
    active = split * n_moe_pos
    cap_n = max(1, int(np.floor(cfg.moe.local_selection_cap * cfg.moe.num_experts)))
    slab = expert_slab_bytes(cfg)
    # memory sized so the full-state slab budget holds exactly the target
    # expert set on every end layer, and a mem_free=0.5 state halves it
    prof = DeviceProfile(
        "end-moe-sim", peak_gflops=END_SIM.peak_gflops,
        mem_gb=2 * active * cap_n * slab / 1e9,
        mem_bw_gbs=END_SIM.mem_bw_gbs, net_gbps=END_SIM.net_gbps,
    )
    eng = EndCloudServingEngine(
        model, params,
        end_profile=prof, cloud_profile=CLOUD_SIM,
        max_batch=max_batch, max_len=128, force_split=split,
    )
    for r in _requests(n_requests, max_new_tokens, seed):
        eng.submit(r)
    for _ in range(6):  # warmup decode
        eng.step()
    m0 = eng.metrics()
    assert m0["expert_hit_rate"] >= 0.95, m0["expert_hit_rate"]
    slabs_full = eng.expert_pool.slabs_in_use

    # -- degradation: memory budget halves -> slab capacity halves, the
    # -- resident set actually SHEDS experts (evictions at a safe point)
    eng.update_device_state(DeviceState(mem_free=0.5))
    for _ in range(6):
        eng.step()
    assert eng.n_expert_evictions > 0, "memory halving must evict slabs"
    assert eng.expert_pool.slabs_in_use < slabs_full
    for lid in eng._active_lids():
        assert eng.expert_pool.resident_count(lid) >= 1

    # -- recovery: the re-grown expert set is prefetched, slab bytes
    # -- booked on the link timeline while decode keeps stepping
    bytes_down_before = eng.expert_bytes_down
    eng.update_device_state(DeviceState(mem_free=1.0))
    for r in _requests(n_requests, max_new_tokens, seed + 1):
        eng.submit(r)
    eng.run()
    m = eng.metrics()
    prefetch_bytes = eng.expert_bytes_down - bytes_down_before
    assert m["expert_prefetches"] > 0 and prefetch_bytes > 0
    # prefetch wire time rides the shared link resource ON TOP of the
    # engine's own boundary/prefill seconds — overlapped with decode, and
    # the pipelining claim still holds
    own_link = eng._stage_busy["link"] + eng._prefill_busy["link"]
    assert eng.timeline.busy_s[eng._res_link] > own_link
    assert m["pipelined_step_s"] < m["serial_step_s"]
    assert m["expert_hit_rate"] >= 0.95, m["expert_hit_rate"]
    # acceptance: per-step expert HBM bytes scale with residents — at the
    # 40% selection cap, at most half the dense [E, d, f] sweep
    assert 0 < m["expert_bytes_step_resident"] <= m["expert_bytes_step_dense"] / 2

    row = {
        "arch": cfg.name,
        "split": m["split"],
        "expert_resident_slabs": m["expert_resident_slabs"],
        "expert_slab_capacity": m["expert_slab_capacity"],
        "expert_hit_rate": round(m["expert_hit_rate"], 4),
        "expert_prefetches": m["expert_prefetches"],
        "expert_evictions": m["expert_evictions"],
        "expert_bytes_down": m["expert_bytes_down"],
        "expert_bytes_up": m["expert_bytes_up"],
        "expert_bytes_step_resident": m["expert_bytes_step_resident"],
        "expert_bytes_step_dense": m["expert_bytes_step_dense"],
        "expert_bytes_ratio": round(
            m["expert_bytes_step_resident"]
            / max(m["expert_bytes_step_dense"], 1), 4
        ),
        "pipelined_step_s": round(m["pipelined_step_s"], 6),
        "serial_step_s": round(m["serial_step_s"], 6),
    }
    print(
        f"[decode_pipeline:experts] residents {row['expert_resident_slabs']}"
        f"/{row['expert_slab_capacity']} slabs, hit {row['expert_hit_rate']}, "
        f"{row['expert_evictions']} evictions on mem-halve, "
        f"{row['expert_prefetches']} prefetches "
        f"({row['expert_bytes_down']/1024:.1f}KiB on the link timeline), "
        f"step expert bytes x{row['expert_bytes_ratio']} of dense",
        flush=True,
    )
    return row


def run_quant(
    *,
    arch: str = "tinyllama-1.1b",
    moe_arch: str = "llama4-scout-17b-16e",
    num_layers: int = 4,
    n_requests: int = 8,
    max_new_tokens: int = 8,
    max_batch: int = 4,
    seed: int = 0,
) -> Dict:
    """Quantized byte streams: the same workload through the f32-path
    engine and the int8 engine (KV pages + boundary payloads + expert
    slabs), asserting the ~2x reduction on each stream and the greedy
    parity tolerance.  Dense baselines are priced at dense dtypes, so
    quantizing the storage must not move any denominator."""
    from repro.core.expertpool import expert_slab_bytes

    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rank = max(cfg.d_model // 4, 1)
    split = 2  # interior split: the boundary actually crosses the wire

    def drive(compression_rank, **quant):
        eng = EndCloudServingEngine(
            model, params,
            end_profile=END_SIM, cloud_profile=CLOUD_SIM,
            max_batch=max_batch, max_len=128,
            compression_rank=compression_rank, force_split=split, **quant,
        )
        for r in _requests(n_requests, max_new_tokens, seed):
            eng.submit(r)
        for _ in range(6):  # live sample point, identical tick in both runs
            eng.step()
        kv_mid = eng.kv_metrics()
        done = eng.run()
        toks = {r.request_id: list(r.generated) for r in done}
        return eng.metrics(), kv_mid, toks

    # parity pair: uncompressed boundary, so int8 quantization is the ONLY
    # perturbation between the two runs (at smoke scale the rank-r codec
    # itself leaves greedy logits near-tied, which would conflate codec
    # loss with quantization noise in the match rate)
    m_ref, kv_ref, tok_ref = drive(0)
    m_q, kv_q, tok_q = drive(0, quantize_kv=True, quantize_boundary=True)

    assert set(tok_q) == set(tok_ref)
    total = sum(len(t) for t in tok_ref.values())
    matched = sum(
        int(a == b)
        for rid in tok_ref
        for a, b in zip(tok_ref[rid], tok_q[rid])
    )
    match_rate = matched / max(total, 1)
    # boundary stream: int8 codes + one f16 scale per row after the rank-r
    # encode -> (r + 2) / (2 r) of the f32-path payload
    up_ratio = m_q["bytes_up"] / max(m_ref["bytes_up"], 1)
    # attention stream at the same live tick: identical mapped pages, int8
    # K/V plus the per-token f16 scale sidecar riding the page table
    attn_ratio = (
        kv_q["attn_bytes_paged_step"] / max(kv_ref["attn_bytes_paged_step"], 1)
    )
    assert 0 < up_ratio <= 0.55, f"boundary bytes ratio {up_ratio}"
    assert 0 < attn_ratio <= 0.55, f"attention bytes ratio {attn_ratio}"
    assert kv_q["kv_capacity_ratio"] >= 1.9, kv_q["kv_capacity_ratio"]
    assert kv_ref["kv_capacity_ratio"] == 1.0, kv_ref["kv_capacity_ratio"]
    assert kv_q["attn_bytes_dense_step"] == kv_ref["attn_bytes_dense_step"]
    assert match_rate >= 0.85, (
        f"quantized greedy decode matched only {matched}/{total} tokens"
    )

    # codec composition: the quantizer is a SECOND stage after the rank-r
    # low-rank encode — int8 codes + f16 scale over r components lands at
    # (r + 2) / (2 r) of the compressed f32-path payload
    m_cref, _, _ = drive(rank)
    m_cq, _, tok_cq = drive(
        rank, quantize_kv=True, quantize_boundary=True)
    comp_ratio = m_cq["bytes_up"] / max(m_cref["bytes_up"], 1)
    assert 0 < comp_ratio <= 0.55, f"compressed boundary ratio {comp_ratio}"
    assert sum(len(t) for t in tok_cq.values()) == total  # no stall/loss

    # -- expert-weight stream (MoE): halve -> recover so the re-grown set
    # -- is PREFETCHED and bytes_down meters real slab wire in both runs.
    # -- The budget is sized in the engine's own STORED slab size so both
    # -- runs hold the same slab count and the ratio isolates bytes/slab.
    cfg_e = smoke_config(get_config(moe_arch)).replace(num_layers=4)
    model_e = build_model(cfg_e)
    params_e = model_e.init(jax.random.PRNGKey(seed))
    n_moe = sum(1 for s in cfg_e.layer_pattern if s.moe)
    cap_n = max(1, int(np.floor(
        cfg_e.moe.local_selection_cap * cfg_e.moe.num_experts)))

    def drive_expert(qe):
        slab = expert_slab_bytes(cfg_e, quantized=qe)
        prof = DeviceProfile(
            "end-moe-sim", peak_gflops=END_SIM.peak_gflops,
            mem_gb=2 * n_moe * cap_n * slab / 1e9,
            mem_bw_gbs=END_SIM.mem_bw_gbs, net_gbps=END_SIM.net_gbps,
        )
        eng = EndCloudServingEngine(
            model_e, params_e,
            end_profile=prof, cloud_profile=CLOUD_SIM,
            max_batch=max_batch, max_len=128, force_split=1,
            quantize_experts=qe,
        )
        for r in _requests(n_requests, max_new_tokens, seed):
            eng.submit(r)
        for _ in range(4):
            eng.step()
        eng.update_device_state(DeviceState(mem_free=0.5))
        for _ in range(4):
            eng.step()
        b0 = eng.expert_bytes_down
        eng.update_device_state(DeviceState(mem_free=1.0))
        eng.run()
        return eng.metrics(), eng.expert_bytes_down - b0

    me_ref, pf_ref = drive_expert(False)
    me_q, pf_q = drive_expert(True)
    assert pf_ref > 0 and pf_q > 0, (pf_ref, pf_q)
    down_ratio = pf_q / pf_ref
    assert down_ratio <= 0.55, f"expert slab wire ratio {down_ratio}"
    assert me_q["expert_capacity_ratio"] >= 1.9, me_q["expert_capacity_ratio"]
    assert me_ref["expert_capacity_ratio"] == 1.0
    assert me_q["expert_bytes_step_dense"] == me_ref["expert_bytes_step_dense"]

    row = {
        "phase": "quantized_streams",
        "arch": cfg.name,
        "moe_arch": cfg_e.name,
        "split": split,
        "compression_rank": rank,
        "greedy_match_rate": round(match_rate, 4),
        "boundary_bytes_up": m_q["bytes_up"],
        "boundary_bytes_up_f32path": m_ref["bytes_up"],
        "boundary_bytes_ratio": round(up_ratio, 4),
        "boundary_bytes_ratio_compressed": round(comp_ratio, 4),
        "attn_bytes_paged_step": kv_q["attn_bytes_paged_step"],
        "attn_bytes_paged_step_f32path": kv_ref["attn_bytes_paged_step"],
        "attn_bytes_quant_ratio": round(attn_ratio, 4),
        "kv_capacity_ratio": round(kv_q["kv_capacity_ratio"], 4),
        "expert_prefetch_bytes_down": pf_q,
        "expert_prefetch_bytes_down_f32path": pf_ref,
        "expert_bytes_quant_ratio": round(down_ratio, 4),
        "expert_capacity_ratio": round(me_q["expert_capacity_ratio"], 4),
    }
    print(
        f"[decode_pipeline:quant] greedy match {matched}/{total} "
        f"({row['greedy_match_rate']}); bytes ratios: "
        f"boundary x{row['boundary_bytes_ratio']} "
        f"(x{row['boundary_bytes_ratio_compressed']} after rank-{rank} encode), "
        f"attention x{row['attn_bytes_quant_ratio']}, "
        f"expert slabs x{row['expert_bytes_quant_ratio']}; "
        f"capacity: kv x{row['kv_capacity_ratio']}, "
        f"experts x{row['expert_capacity_ratio']}",
        flush=True,
    )
    return row


def run_spec(
    *,
    arch: str = "tinyllama-1.1b",
    num_layers: int = 4,
    n_requests: int = 6,
    max_new_tokens: int = 16,
    max_batch: int = 4,
    link_rtt_ms: float = 60.0,
    spec_k: int = 8,
    seed: int = 0,
) -> Dict:
    """Speculative multi-token decode across a link-bound boundary.

    In the RTT-dominated regime every non-speculative decode round pays
    one link round trip for one token; the end tier drafting k tokens and
    the cloud verifying them in one C=k chunk amortizes that round trip
    over the accepted prefix.  Asserted:

      * greedy tokens bit-identical to the non-speculative engine at
        splits 0 / mid / R (the rollback-and-correct rule makes parity
        structural, not statistical — f32 config so argmax ties are
        deterministic across the chunked and decode paths);
      * >= 1.4x tokens per boundary round trip at acceptance >= 0.6, and
        a shorter modeled decode span, in the link-bound scenario;
      * with the RTT override at 0 (compute-bound), the planner
        auto-disables speculation (k=1): zero spec rounds, and the step
        count matches the plain engine exactly — no overhead.
    """
    from repro.serving.common import VirtualClock

    cfg = smoke_config(get_config(arch)).replace(
        num_layers=num_layers, dtype="float32"
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    R = cfg.block_repeat
    rtt_s = link_rtt_ms * 1e-3

    def drive(split, k, rtt):
        eng = EndCloudServingEngine(
            model, params,
            end_profile=END_SIM, cloud_profile=CLOUD_SIM,
            max_batch=max_batch, max_len=64, force_split=split,
            timing="modeled", clock=VirtualClock(),
            spec_k=k, link_rtt_s=rtt,
        )
        reqs = _requests(n_requests, max_new_tokens, seed)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        m = eng.metrics()
        toks = {r.request_id: list(r.generated) for r in done}
        return toks, m

    # -- exact-greedy-parity contract at splits 0 / mid / R ------------------
    for split in (0, R // 2, R):
        tok_ref, _ = drive(split, 1, rtt_s)
        tok_spec, m_s = drive(split, spec_k, rtt_s)
        assert tok_spec == tok_ref, (
            f"speculative greedy tokens diverged at split {split}"
        )
        assert m_s["spec_rounds"] > 0, (
            f"link-bound run at split {split} never speculated: {m_s}"
        )

    # -- link-bound speedup: tokens per boundary round trip ------------------
    split = R // 2
    tok_base, m_base = drive(split, 1, rtt_s)
    tok_spec, m_spec = drive(split, spec_k, rtt_s)
    tokens = sum(len(t) for t in tok_base.values())
    base_tpr = tokens / max(m_base["n_stage_steps"], 1)
    spec_tpr = tokens / max(m_spec["n_stage_steps"], 1)
    speedup = spec_tpr / max(base_tpr, 1e-12)
    acceptance = m_spec["spec_acceptance_rate"]
    assert acceptance >= 0.6, (
        f"acceptance {acceptance} < 0.6 — the dense draft should be exact"
    )
    assert speedup >= 1.4, (
        f"tokens per boundary round trip improved only x{speedup:.2f} "
        f"({base_tpr:.2f} -> {spec_tpr:.2f}) at acceptance {acceptance}"
    )
    # and the modeled decode span (RTT rides every link occupancy) shrinks
    assert m_spec["pipelined_total_s"] < m_base["pipelined_total_s"], (
        m_spec["pipelined_total_s"], m_base["pipelined_total_s"],
    )

    # -- compute-bound regime: speculation must auto-disable, zero overhead --
    tok_cb_ref, m_cb_ref = drive(split, 1, 0.0)
    tok_cb, m_cb = drive(split, spec_k, 0.0)
    assert m_cb["spec_plan_k"] == 1, m_cb["spec_plan_k"]
    assert m_cb["spec_rounds"] == 0
    assert tok_cb == tok_cb_ref
    assert m_cb["n_stage_steps"] == m_cb_ref["n_stage_steps"], (
        m_cb["n_stage_steps"], m_cb_ref["n_stage_steps"],
    )

    row = {
        "phase": "speculative_decode",
        "arch": cfg.name,
        "split": split,
        "link_rtt_ms": link_rtt_ms,
        "spec_k_budget": spec_k,
        "spec_plan_k": m_spec["spec_plan_k"],
        "spec_k_eff": m_spec["spec_k_eff"],
        "spec_rounds": m_spec["spec_rounds"],
        "spec_drafted": m_spec["spec_drafted"],
        "spec_accepted": m_spec["spec_accepted"],
        "spec_acceptance_rate": acceptance,
        "spec_rollbacks": m_spec["spec_rollbacks"],
        "tokens": tokens,
        "base_tokens_per_round": round(base_tpr, 4),
        "spec_tokens_per_round": round(spec_tpr, 4),
        "spec_speedup": round(speedup, 3),
        "base_decode_span_s": round(m_base["pipelined_total_s"], 4),
        "spec_decode_span_s": round(m_spec["pipelined_total_s"], 4),
        "computebound_plan_k": m_cb["spec_plan_k"],
        "greedy_parity": 1.0,
        "n_host_syncs": m_spec["n_host_syncs"],
        "n_host_syncs_base": m_base["n_host_syncs"],
    }
    print(
        f"[decode_pipeline:spec] rtt={link_rtt_ms}ms k={row['spec_plan_k']} "
        f"(eff {row['spec_k_eff']}): {row['base_tokens_per_round']} -> "
        f"{row['spec_tokens_per_round']} tokens/round (x{row['spec_speedup']}) "
        f"at acceptance {acceptance}, decode span "
        f"{row['base_decode_span_s']}s -> {row['spec_decode_span_s']}s; "
        f"compute-bound plan k={row['computebound_plan_k']} (auto-disabled), "
        f"greedy parity exact at splits 0/{R // 2}/{R}",
        flush=True,
    )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_decode_pipeline.json")
    ap.add_argument("--rank", type=int, default=0)
    # tiny-shape knobs so CI can smoke the overlap / no-stall assertions
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    # link-bound speculative-decode scenario (bandwidth-constrained round
    # trips; 0 disables the scenario's RTT and exercises only auto-disable)
    ap.add_argument("--link-rtt-ms", type=float, default=60.0)
    ap.add_argument("--spec-k", type=int, default=8)
    args = ap.parse_args()
    rows = [run(
        compression_rank=args.rank,
        num_layers=args.layers,
        n_requests=args.requests,
        max_new_tokens=args.new_tokens,
        max_batch=args.max_batch,
    )]
    rows.append(run_expert(
        num_layers=4,  # R=4 puts the planner's optimum at split 1
        n_requests=args.requests,
        max_new_tokens=args.new_tokens,
        max_batch=args.max_batch,
    ))
    rows.append(run_quant(
        num_layers=4,  # interior split 2 of R=4 puts the boundary on the wire
        max_batch=min(args.max_batch, 4),
    ))
    rows.append(run_spec(
        num_layers=args.layers,
        max_batch=min(args.max_batch, 4),
        link_rtt_ms=args.link_rtt_ms,
        spec_k=args.spec_k,
    ))
    json.dump(rows, open(args.out, "w"), indent=1)
    # stable machine-readable artifact name for CI collection, regardless
    # of --out
    if args.out != "BENCH_decode_pipeline.json":
        json.dump(rows, open("BENCH_decode_pipeline.json", "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
