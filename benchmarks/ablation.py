"""Ablation study: remove (1) HL-GGN and (2) PO-ECC (paper §Ablation).

Paper's findings to reproduce qualitatively:
  - HL-GGN   : accuracy -2.1%, latency +23%
  - PO-ECC   : throughput -38%, latency +45%
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List

import numpy as np

from repro.configs.switch_base import with_experts
from repro.data.pipeline import DataConfig
from repro.sim.policies import PolicyConfig, ec2moe_stages, make_requests
from repro.sim.simulator import Link, SimRequest, Stage, poisson_arrivals, simulate

from benchmarks.common import eval_tiny, tiny_switch, train_tiny


def accuracy_ablation(E: int = 16, steps: int = 300, seed: int = 0) -> Dict:
    """EC2MoE vs EC2MoE-without-HL-GGN (flat gate, no hardware-aware
    selection -> compression noise hits an unstructured router)."""
    out = {}
    dcfg = DataConfig(task="glue_proxy", vocab_size=512, seq_len=64,
                      n_latent_tasks=16, seed=seed)
    full_cfg = tiny_switch(E, "ec2moe")
    m1, s1 = train_tiny(full_cfg, dcfg, steps=steps, seed=seed)
    out["ec2moe"] = eval_tiny(m1, s1["params"], dcfg) * 100
    flat_cfg = full_cfg.replace(
        moe=dataclasses.replace(full_cfg.moe, num_groups=1)
    )
    m2, s2 = train_tiny(flat_cfg, dcfg, steps=steps, seed=seed)
    out["no_hlggn"] = eval_tiny(m2, s2["params"], dcfg) * 100
    out["acc_delta_pct"] = out["no_hlggn"] - out["ec2moe"]
    return out


def perf_ablation(E: int = 16, op_rate: float = 8.0, sat_rate: float = 60.0,
                  n: int = 240, seed: int = 0):
    """Throughput measured at saturation; latency at the loaded operating
    point (EC2MoE base uses its load-aware plan, as in fig. 5/6)."""
    cfg = with_experts(E)
    pc = PolicyConfig()
    arr_sat = poisson_arrivals(sat_rate, n, seed)
    arr_op = poisson_arrivals(op_rate, n, seed + 1)

    def run(reqs):
        return simulate(reqs, link=Link(0.3, seed=seed),
                        end_servers=pc.n_end_devices,
                        cloud_servers=pc.n_cloud_gpus)

    def reqs_from(proto, arrivals):
        return [
            SimRequest(i, float(t),
                       [Stage(s.resource, s.service_s, s.payload_bytes, s.jitter)
                        for s in proto])
            for i, t in enumerate(arrivals)
        ]

    base_sat = run(make_requests("ec2moe", cfg, pc, arr_sat, offered_rps=0))
    base_op = run(make_requests("ec2moe", cfg, pc, arr_op, offered_rps=op_rate))

    # -HL-GGN: without hardware-aware selection the end tier cannot host
    # experts, so MoE layers (and the lost gate saving) move to the cloud;
    # the end keeps only the dense front (~25% of its planned compute).
    def no_hlggn_proto(proto):
        out, moved = [], 0.0
        end_rate = pc.end_profile.peak_gflops * pc.end_efficiency
        cloud_rate = pc.cloud_profile.peak_gflops * pc.cloud_efficiency
        for s in proto:
            if s.resource == "end":
                out.append(Stage("end", s.service_s * 0.25))
                moved += s.service_s * 0.75
            elif s.resource == "cloud":
                out.append(Stage("cloud",
                                 s.service_s + moved * end_rate / cloud_rate,
                                 jitter=s.jitter))
            else:
                out.append(s)
        return out

    nh_sat = run(reqs_from(no_hlggn_proto(
        ec2moe_stages(cfg, pc, offered_rps=0)), arr_sat))
    nh_op = run(reqs_from(no_hlggn_proto(
        ec2moe_stages(cfg, pc, offered_rps=op_rate)), arr_op))

    # -PO-ECC: no compression, no pipelining: the request executes serially
    # while HOLDING the end device (no cross-request overlap), raw boundary.
    proto = ec2moe_stages(cfg, dataclasses.replace(pc, compression_rank=0),
                          offered_rps=0)
    link = Link(0.3, seed=seed)
    total = sum(
        (link.rtt_s / 2 + s.payload_bytes * 8 / 0.3e9)
        if s.resource == "link" else s.service_s
        for s in proto
    )
    np_sat = run([SimRequest(i, float(t), [Stage("end", total)])
                  for i, t in enumerate(arr_sat)])
    np_op = run([SimRequest(i, float(t), [Stage("end", total)])
                 for i, t in enumerate(arr_op)])

    return {
        "latency_increase_no_hlggn_pct": 100 * (
            nh_op["latency_mean_s"] / base_op["latency_mean_s"] - 1
        ),
        "throughput_drop_no_hlggn_pct": 100 * (
            1 - nh_sat["throughput_rps"] / base_sat["throughput_rps"]
        ),
        "throughput_drop_no_poecc_pct": 100 * (
            1 - np_sat["throughput_rps"] / base_sat["throughput_rps"]
        ),
        "latency_increase_no_poecc_pct": 100 * (
            np_op["latency_mean_s"] / base_op["latency_mean_s"] - 1
        ),
        "base_sat_rps": base_sat["throughput_rps"],
        "base_op_latency_s": base_op["latency_mean_s"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="bench_ablation.json")
    args = ap.parse_args()
    acc = accuracy_ablation(steps=args.steps)
    perf = perf_ablation()
    print("[ablation] accuracy:", {k: round(v, 2) for k, v in acc.items()})
    print("[ablation] -HL-GGN latency:",
          f"+{perf['latency_increase_no_hlggn_pct']:.0f}% (paper: +23%)")
    print("[ablation] -PO-ECC throughput:",
          f"-{perf['throughput_drop_no_poecc_pct']:.0f}% (paper: -38%), "
          f"latency +{perf['latency_increase_no_poecc_pct']:.0f}% (paper: +45%)")
    json.dump({"accuracy": acc, "perf": {k: v for k, v in perf.items()
                                          if not isinstance(v, dict)}},
              open(args.out, "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
