"""Table 1: accuracy of EC2MoE vs BrownoutServe vs EdgeMoE, across expert
counts, on the two proxy datasets (GLUE/SQuAD stand-ins; see
repro.data.pipeline for the task definitions and EXPERIMENTS.md for the
proxy rationale — this container is offline).

Per cell: train a smoke-scale Switch-Base variant (paper setting: top-1,
seq 256 -> scaled to 64, batch 4 -> 16) under each system's constraints and
evaluate under its serving conditions:
  ec2moe        — group gate + jointly-trained dispatch compression
  brownoutserve — flat gate, full experts, eval with p_net expert loss
  edgemoe       — flat gate, static 40% expert subset (train + eval)
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.data.pipeline import DataConfig

from benchmarks.common import (
    SYSTEMS,
    eval_tiny,
    static_mask,
    tiny_switch,
    train_tiny,
)


def run(
    expert_counts=(8, 16, 32, 64),
    datasets=("glue_proxy", "squad_proxy"),
    steps: int = 300,
    p_net: float = 0.01,
    seed: int = 0,
) -> List[Dict]:
    rows = []
    for ds in datasets:
        for E in expert_counts:
            dcfg = DataConfig(task=ds, vocab_size=512, seq_len=64,
                              n_latent_tasks=16, seed=seed)
            for system in SYSTEMS:
                cfg = tiny_switch(E, system)
                train_mask = (
                    static_mask(E, cfg.moe.local_selection_cap)
                    if system == "edgemoe"
                    else None
                )
                model, st = train_tiny(
                    cfg, dcfg, steps=steps, train_mask=train_mask, seed=seed
                )
                acc = eval_tiny(
                    model,
                    st["params"],
                    dcfg,
                    expert_mask=train_mask,
                    drop_p=(p_net if system == "brownoutserve" else 0.0),
                )
                rows.append(
                    dict(dataset=ds, experts=E, system=system,
                         accuracy=round(acc * 100, 2), steps=steps)
                )
                print(f"[table1] {ds} E={E} {system}: acc={acc*100:.2f}%",
                      flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", default="8,16,32,64")
    ap.add_argument("--datasets", default="glue_proxy,squad_proxy")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="bench_table1.json")
    args = ap.parse_args()
    rows = run(
        tuple(int(e) for e in args.experts.split(",")),
        tuple(args.datasets.split(",")),
        steps=args.steps,
    )
    json.dump(rows, open(args.out, "w"), indent=1)
    # paper-style summary: per-system mean accuracy
    for ds in set(r["dataset"] for r in rows):
        line = {s: [] for s in SYSTEMS}
        for r in rows:
            if r["dataset"] == ds:
                line[r["system"]].append(r["accuracy"])
        means = {s: sum(v) / len(v) for s, v in line.items() if v}
        print(f"[table1] {ds} means:", means)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
