"""Chaos replay benchmark: the serve_load trace under an injected fault
schedule, with recovery invariants asserted.

Replays one seeded arrival trace through the fleet engine twice on the
modeled clock:

  * ``clean`` — no faults: the PR 6 load-harness baseline.
  * ``chaos`` — the same trace with a deterministic fault schedule fired
    against it: one lane crash (later recovered), one link blackout
    window on a surviving lane (driving that lane's cloud-only replan),
    and a burst of flaky boundary transfers (retried under bounded
    backoff).

Asserted invariants (the PR's acceptance bar):

  * zero lost and zero duplicated requests under chaos — lane death
    migrates in-flight decode via the spill/restore path, it never drops;
  * greedy tokens bit-identical chaos-vs-clean for EVERY request (the
    boundary runs uncompressed here, so migration, split-0 degradation
    and retries are pure *scheduling* perturbations);
  * bounded interactive p99 TTFT inflation: ``p99_chaos <= p99_clean +
    fault_window_s + slack`` where ``fault_window_s`` is the total
    injected unavailability (crash window + blackout window) — a faulted
    request can be delayed by a window, but recovery must not let delays
    compound past it;
  * per-seed determinism: a repeat chaos run reproduces tokens, fire log
    and summaries bit-for-bit;
  * compressed-boundary chaos (``compression_rank > 0``): faults that
    change WHERE a request computes (migration off a dead lane, a
    placement shifted downstream of one, split-0 degradation on the
    blacked-out lane) legitimately change its tokens, because the codec
    truncates the boundary at the planned split.  The affected set is
    derived from the fire log plus the placement log, and every request
    OUTSIDE it must stay bit-identical chaos-vs-clean.

Report: ``BENCH_serve_chaos.json`` with both runs' per-class summaries,
the fleet fault counters (``lane_failures``, ``migrations``,
``migration_spill_bytes``, ``transfer_retries``, ``degraded_ticks``,
``link_blackout_s``), and the fired schedule.

    PYTHONPATH=src python -m benchmarks.serve_chaos [--n-requests 600]
        [--lanes 3] [--seed 0] [--out BENCH_serve_chaos.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict

import jax

from repro.configs import get_config, smoke_config
from repro.models.model import build_model
from repro.serving.common import VirtualClock
from repro.serving.faults import ChaosInjector, FaultEvent, FaultSchedule
from repro.serving.fleet import FleetServingEngine
from repro.serving.loadgen import (
    BATCH,
    INTERACTIVE,
    build_schedule,
    drive,
    poisson_arrivals,
    summarize,
)

from benchmarks.fleet_throughput import CLOUD, FLEET_PROFILES

# Fault counters every chaos report carries (and serve_load reports as
# all-zero on its fault-free runs).
FAULT_KEYS = (
    "lane_failures", "lane_recoveries", "migrations", "migration_restores",
    "migration_spill_bytes", "transfer_retries", "degraded_ticks",
    "link_blackout_s", "cloud_server_failures",
)


def _build_engine(model, params, *, n_lanes: int, max_batch: int,
                  compression_rank: int = 0) -> FleetServingEngine:
    # compression_rank=0 (the default): the boundary is exact, so
    # chaos-vs-clean token parity is total — any divergence is a recovery
    # bug, not codec noise.  The compressed-parity phase re-runs with
    # rank>0, where parity is asserted on the fault-unaffected set only.
    return FleetServingEngine(
        model, params,
        end_profiles=FLEET_PROFILES[:n_lanes],
        cloud_profile=CLOUD,
        cloud_servers=2,
        compression_rank=compression_rank,
        max_batch=max_batch, max_len=160,
        timing="modeled", max_spill=1.0,
        clock=VirtualClock(),
    )


def _fault_schedule(horizon_s: float, n_lanes: int) -> FaultSchedule:
    """The benchmark's declared chaos: timed against the trace horizon so
    the faults land while the fleet is under load at any request count."""
    # crash a mid-fleet lane placement actually loads (the last lane is
    # the straggler profile and often sits idle under max_spill), black
    # out the strongest lane's link — both faults must hit live traffic
    crash_lane = 1 if n_lanes > 1 else 0
    blackout_lane = 0
    nominal = FLEET_PROFILES[blackout_lane].net_gbps
    return FaultSchedule([
        FaultEvent(0.10 * horizon_s, "transfer_flaky", device=0, count=3),
        FaultEvent(0.20 * horizon_s, "lane_crash", device=crash_lane),
        FaultEvent(0.45 * horizon_s, "lane_recover", device=crash_lane),
        FaultEvent(0.55 * horizon_s, "link_blackout", device=blackout_lane),
        FaultEvent(0.75 * horizon_s, "link_recover", device=blackout_lane,
                   gbps=nominal),
    ])


def _one_run(model, params, arrivals, classes, seed, *, n_lanes, max_batch,
             chaos: bool, compression_rank: int = 0):
    schedule = build_schedule(arrivals, classes, seed + 1)
    eng = _build_engine(model, params, n_lanes=n_lanes, max_batch=max_batch,
                        compression_rank=compression_rank)
    injector = None
    if chaos:
        horizon = float(arrivals[-1])
        injector = ChaosInjector(
            _fault_schedule(horizon, n_lanes), eng
        )
    reqs = drive(eng, schedule)
    return eng, reqs, injector


def run(
    *,
    arch: str = "tinyllama-1.1b",
    num_layers: int = 2,
    n_requests: int = 600,
    rate_rps: float = 800.0,
    n_lanes: int = 3,
    max_batch: int = 2,
    warmup_frac: float = 0.05,
    seed: int = 0,
    p99_slack_s: float = 0.05,
) -> Dict:
    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    arrivals = poisson_arrivals(n_requests, rate_rps, seed)
    warmup_s = float(arrivals[int(len(arrivals) * warmup_frac)])
    horizon = float(arrivals[-1])
    classes = (dataclasses.replace(INTERACTIVE, ttft_slo_s=0.2), BATCH)

    runs: Dict[str, Dict] = {}
    tokens: Dict[str, Dict[int, list]] = {}
    fire_logs: Dict[str, list] = {}
    for name, chaos in (("clean", False), ("chaos", True), ("chaos2", True)):
        eng, reqs, injector = _one_run(
            model, params, arrivals, classes, seed,
            n_lanes=n_lanes, max_batch=max_batch, chaos=chaos,
        )
        m = eng.metrics()
        row = {
            "all": summarize(reqs, warmup_s=warmup_s),
            "interactive": summarize(reqs, warmup_s=warmup_s, priority=0),
            "batch": summarize(reqs, warmup_s=warmup_s,
                               priority=BATCH.priority),
            **{k: m[k] for k in FAULT_KEYS},
        }
        # exactly-once: nothing dropped, nothing finished twice
        assert row["all"]["dropped"] == 0, f"{name}: lost requests: {row}"
        ids = [r.request_id for r in eng.finished]
        assert len(ids) == len(set(ids)) == n_requests, (
            f"{name}: {len(ids)} finishes over {len(set(ids))} unique ids"
        )
        tokens[name] = {r.request_id: list(r.generated) for r in reqs}
        if injector is not None:
            assert injector.pending == 0, "declared faults never fired"
            fire_logs[name] = injector.fire_log()
            row["fired"] = fire_logs[name]
        runs[name] = row
        print(
            f"[serve_chaos] {name:6s} interactive "
            f"ttft_p99={row['interactive']['ttft_p99']:.3f}s "
            f"migrations={row['migrations']} "
            f"retries={row['transfer_retries']} "
            f"blackout={row['link_blackout_s']:.2f}s "
            f"(finished={row['all']['finished']}/{n_requests})",
            flush=True,
        )

    # greedy-token parity: chaos only moves WHEN tokens happen, never which
    diverged = [
        rid for rid in tokens["clean"]
        if tokens["clean"][rid] != tokens["chaos"][rid]
    ]
    assert not diverged, f"tokens diverged under chaos: requests {diverged}"

    # per-seed determinism: repeat chaos run is bit-identical
    assert tokens["chaos"] == tokens["chaos2"], "chaos rerun tokens differ"
    assert fire_logs["chaos"] == fire_logs["chaos2"], "fire logs differ"
    assert runs["chaos"] == runs["chaos2"], "chaos rerun summaries differ"

    # bounded p99 inflation: the documented bound is the total *measured*
    # unavailability (crash outage from the fire log — events land at the
    # first tick past their time on a coarse modeled clock, so the
    # declared window underestimates — plus the metered blackout seconds)
    # plus a fixed slack for retry backoff and replan latency.  Recovery
    # may cost a faulted request one outage window; it must never let
    # delays compound past it.
    fired = {(d["kind"], d["device"]): d["t_fired_s"]
             for d in fire_logs["chaos"]}
    crash_lane = 1 if n_lanes > 1 else 0
    crash_outage_s = (
        fired[("lane_recover", crash_lane)] - fired[("lane_crash", crash_lane)]
    )
    fault_window_s = crash_outage_s + runs["chaos"]["link_blackout_s"]
    p99_clean = runs["clean"]["interactive"]["ttft_p99"]
    p99_chaos = runs["chaos"]["interactive"]["ttft_p99"]
    bound = p99_clean + fault_window_s + p99_slack_s
    assert p99_chaos <= bound, (
        f"interactive p99 TTFT inflation unbounded: chaos {p99_chaos:.3f}s "
        f"> clean {p99_clean:.3f}s + window {fault_window_s:.3f}s "
        f"+ slack {p99_slack_s}s"
    )
    assert runs["chaos"]["lane_failures"] == 1
    assert runs["chaos"]["migration_restores"] == runs["chaos"]["migrations"]
    print(
        f"[serve_chaos] p99 bound holds: chaos {p99_chaos:.3f}s <= "
        f"clean {p99_clean:.3f}s + fault window {fault_window_s:.3f}s "
        f"+ slack {p99_slack_s}s; parity exact over {n_requests} requests",
        flush=True,
    )
    runs["chaos2"] = "identical to chaos (asserted)"  # keep the JSON small

    # ---- compressed-boundary chaos: parity on the fault-unaffected set.
    # With rank > 0 the codec truncates the boundary activation at the
    # *planned split*, so a fault that moves a request to a different
    # lane (migration, or a placement shifted downstream of one) or
    # changes its lane's split (the blacked-out lane degrades to split 0)
    # legitimately changes its tokens.  The affected set is exactly those
    # requests, read off the fire log + placement log; everything outside
    # it took the same codec path and must stay bit-identical.
    rank = max(cfg.d_model // 4, 1)
    comp_tokens: Dict[str, Dict[int, list]] = {}
    comp_placed: Dict[str, Dict[int, list]] = {}
    comp_fired: list = []
    for name, chaos in (("clean", False), ("chaos", True)):
        eng, reqs, injector = _one_run(
            model, params, arrivals, classes, seed,
            n_lanes=n_lanes, max_batch=max_batch, chaos=chaos,
            compression_rank=rank,
        )
        ids = [r.request_id for r in eng.finished]
        assert len(ids) == len(set(ids)) == n_requests, (
            f"compressed {name}: exactly-once violated"
        )
        comp_tokens[name] = {r.request_id: list(r.generated) for r in reqs}
        comp_placed[name] = {}
        for p in eng.placed:
            comp_placed[name].setdefault(p["request_id"], []).append(
                p["device"]
            )
        if injector is not None:
            assert injector.pending == 0, "declared faults never fired"
            comp_fired = injector.fire_log()
    # lanes whose split changed under chaos: the whole blackout window is
    # a degradation hazard, so the lane is excluded wholesale
    degraded_lanes = {
        d["device"] for d in comp_fired if d["kind"] == "link_blackout"
    }
    affected = {
        rid for rid in comp_tokens["clean"]
        # placed differently than the clean run (fault-shifted placement)
        if comp_placed["clean"].get(rid) != comp_placed["chaos"].get(rid)
        # migrated off a dead lane (restored at the destination's split)
        or len(comp_placed["chaos"].get(rid, [])) > 1
        # ran on a lane that degraded its split during the blackout
        or degraded_lanes & set(comp_placed["chaos"].get(rid, []))
    }
    unaffected = sorted(set(comp_tokens["clean"]) - affected)
    assert unaffected, (
        "chaos touched every request: compressed parity set is empty"
    )
    comp_diverged = [
        rid for rid in unaffected
        if comp_tokens["clean"][rid] != comp_tokens["chaos"][rid]
    ]
    assert not comp_diverged, (
        f"rank-{rank} tokens diverged for fault-UNAFFECTED requests "
        f"{comp_diverged[:8]} (of {len(comp_diverged)})"
    )
    print(
        f"[serve_chaos] compressed (rank={rank}): "
        f"{len(affected)} affected / {len(unaffected)} unaffected — "
        f"unaffected parity exact",
        flush=True,
    )

    return {
        "arch": cfg.name,
        "n_requests": n_requests,
        "rate_rps": rate_rps,
        "n_lanes": n_lanes,
        "max_batch": max_batch,
        "cloud_servers": 2,
        "seed": seed,
        "warmup_s": round(warmup_s, 3),
        "horizon_s": round(horizon, 3),
        "fault_window_s": round(fault_window_s, 3),
        "p99_slack_s": p99_slack_s,
        "p99_bound_s": round(bound, 4),
        "token_parity": "exact",
        "compressed": {
            "compression_rank": rank,
            "affected": len(affected),
            "unaffected": len(unaffected),
            "token_parity": "exact on unaffected set",
        },
        "runs": runs,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=600)
    ap.add_argument("--rate-rps", type=float, default=800.0)
    ap.add_argument("--lanes", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-layers", type=int, default=2)
    ap.add_argument("--p99-slack", type=float, default=0.05)
    ap.add_argument("--out", default="BENCH_serve_chaos.json")
    args = ap.parse_args()
    report = run(
        num_layers=args.num_layers,
        n_requests=args.n_requests,
        rate_rps=args.rate_rps,
        n_lanes=args.lanes,
        max_batch=args.max_batch,
        seed=args.seed,
        p99_slack_s=args.p99_slack,
    )
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[serve_chaos] wrote {args.out}")


if __name__ == "__main__":
    main()
