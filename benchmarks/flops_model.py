"""Analytic per-device FLOP / HBM-byte model for the roofline.

XLA's ``cost_analysis`` counts while-loop bodies ONCE (verified in
EXPERIMENTS.md §Roofline-methodology), so scanned-layer models under-report
by ~block_repeat x.  Since we control every einsum in the implementation,
the compute/memory roofline terms come from this closed-form model of what
the lowered program actually executes — including the warts we know about
(flash attention computes the full S^2 score square without causal block
skipping; MoE capacity buffers compute padding rows; remat recomputes the
forward inside backward).  Collective bytes come from the (trip-count
corrected) HLO parse in repro.launch.dryrun.

Conventions: FLOPs counted as 2*M*N*K per matmul; backward = 2x forward
matmul cost; remat adds +1x forward (recompute).  Bytes = one read of every
matmul operand + one write of outputs at the activation dtype, plus
optimizer state traffic for train.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeCell


@dataclass(frozen=True)
class CellCost:
    flops: float  # per device
    hbm_bytes: float  # per device
    model_flops: float  # 6*N_active*D (train) / 2*N_active*D (inference), global
    detail: Dict[str, float]


def _attn_flops(cfg: ModelConfig, S_q: int, S_kv: int, causal_skip: bool) -> float:
    """Score+PV matmul flops per sequence (one layer, one batch element).
    Without block skipping the full S_q x S_kv square is computed."""
    H, hd = cfg.num_heads, cfg.head_dim
    pairs = S_q * S_kv
    if causal_skip and S_q == S_kv:
        pairs = S_q * (S_q + 1) // 2
    return 2 * 2 * pairs * H * hd  # qk^T and p@v


def _proj_flops(cfg: ModelConfig) -> float:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return 2 * d * (H * hd + 2 * KV * hd + H * hd)  # q, k, v, o per token


def _ffn_flops(cfg: ModelConfig, d_ff: int) -> float:
    mats = 3 if cfg.ffn_gated else 2
    return 2 * mats * cfg.d_model * d_ff  # per token


def _moe_flops_per_token(cfg: ModelConfig, capacity_factor: float) -> float:
    m = cfg.moe
    # capacity padding: buffers are sized k*cf assignments/token; empty rows
    # still run through the grouped GEMM.
    routed = _ffn_flops(cfg, m.d_ff_expert) * m.top_k * capacity_factor
    shared = _ffn_flops(cfg, m.d_ff_expert * m.shared_experts) if m.shared_experts else 0.0
    gate = 2 * cfg.d_model * (m.num_experts + m.num_groups)
    return routed + shared + gate


def _ssm_flops_per_token(cfg: ModelConfig, S: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    P = s.head_dim
    N = s.d_state
    G = s.n_groups
    proj = 2 * d * (2 * d_in + 2 * G * N + H) + 2 * d_in * d  # in/out proj
    conv = 2 * s.d_conv * (d_in + 2 * G * N)
    Q = min(s.chunk_size, S)
    # SSD per token: scores CB^T (Q*G*N), intra mix (Q*H*P), states (H*P*N x2)
    ssd = 2 * Q * G * N + 2 * Q * H * P + 4 * H * P * N
    return proj + conv + ssd


def _embed_head_flops(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.padded_vocab_size  # lm head matmul per token


def _layer_flops_per_token(cfg: ModelConfig, spec, S_q: int, S_kv: int,
                           capacity_factor: float) -> float:
    f = 0.0
    if spec.kind == "attn":
        f += _proj_flops(cfg)
        f += _attn_flops(cfg, S_q, S_kv, causal_skip=False) / max(S_q, 1)
        if spec.cross_attn:
            f += _proj_flops(cfg)
            f += _attn_flops(cfg, S_q, cfg.encoder_seq_len, False) / max(S_q, 1)
    else:
        f += _ssm_flops_per_token(cfg, S_q)
    if spec.moe and cfg.moe:
        f += _moe_flops_per_token(cfg, capacity_factor)
    elif cfg.d_ff:
        f += _ffn_flops(cfg, cfg.d_ff)
    return f


def _params_bytes(cfg: ModelConfig, dtype_bytes: int) -> float:
    return cfg.param_count() * dtype_bytes


def cell_cost(cfg: ModelConfig, cell: ShapeCell, n_devices: int,
              dp: int) -> CellCost:
    """Per-device cost of one step of this cell."""
    B, S = cell.global_batch, cell.seq_len
    mode = cell.mode
    cf = cfg.moe.capacity_factor if cfg.moe else 1.0

    if mode == "decode":
        S_q, S_kv, tokens = 1, S, B  # one new token per slot
    else:
        S_q = S_kv = S
        tokens = B * S

    per_tok = sum(
        _layer_flops_per_token(cfg, spec, S_q if mode != "decode" else 1,
                               S_kv, cf)
        for spec in cfg.layer_pattern
    ) * cfg.block_repeat
    if mode == "decode":
        # decode attention reads the whole cache: per-token attn cost uses S_kv
        attn_extra = sum(
            2 * 2 * S_kv * cfg.num_heads * cfg.head_dim
            for spec in cfg.layer_pattern if spec.kind == "attn"
        ) * cfg.block_repeat
        per_tok += attn_extra
    if cfg.encoder_decoder and mode != "decode":
        enc_tok = cfg.encoder_seq_len * B
        enc_per_tok = (
            _proj_flops(cfg)
            + _attn_flops(cfg, cfg.encoder_seq_len, cfg.encoder_seq_len, False)
            / cfg.encoder_seq_len
            + _ffn_flops(cfg, cfg.d_ff)
        ) * cfg.encoder_layers
    else:
        enc_tok, enc_per_tok = 0, 0.0

    fwd = per_tok * tokens + enc_per_tok * enc_tok + _embed_head_flops(cfg) * tokens
    if mode == "train":
        total = 3 * fwd + fwd  # fwd + 2x bwd + 1x remat recompute
        # optimizer: ~10 flops/param (adam) or ~6 (adafactor), negligible but counted
        total += 10 * cfg.param_count()
    else:
        total = fwd

    flops_per_dev = total / n_devices

    # HBM bytes (per device): weights streamed once per step (sharded),
    # activations written+read once per layer boundary, caches for decode.
    act_bytes = 2  # bf16
    weight_stream = _params_bytes(cfg, 2) / n_devices
    act_traffic = (
        tokens / max(dp, 1) * cfg.d_model * act_bytes
        * cfg.num_layers * 8  # ~8 tensor round-trips per layer
    )
    cache_traffic = 0.0
    if mode == "decode":
        kv_layers = sum(1 for s in cfg.layer_pattern if s.kind == "attn")
        kv_len = min(cfg.sliding_window or S, S)
        cache_traffic = (
            B * kv_len * cfg.num_kv_heads * cfg.head_dim * 2 * act_bytes
            * kv_layers * cfg.block_repeat / n_devices
        )
        ssm_layers = sum(1 for s in cfg.layer_pattern if s.kind == "ssm")
        if ssm_layers and cfg.ssm:
            d_in = cfg.ssm.expand * cfg.d_model
            H = d_in // cfg.ssm.head_dim
            cache_traffic += (
                B * H * cfg.ssm.head_dim * cfg.ssm.d_state * 4 * 2
                * ssm_layers * cfg.block_repeat / n_devices
            )
    if mode == "train":
        # optimizer state read+write (fp32 master + stats)
        opt_mult = 12 if cfg.optimizer == "adamw" else 6
        weight_stream += cfg.param_count() * opt_mult / n_devices
        act_traffic *= 3  # fwd + bwd + remat passes

    hbm = weight_stream + act_traffic + cache_traffic

    n_active = cfg.active_param_count()
    model_flops = (6 if mode == "train" else 2) * n_active * tokens

    return CellCost(
        flops=flops_per_dev,
        hbm_bytes=hbm,
        model_flops=model_flops,
        detail={
            "fwd_flops_global": fwd,
            "tokens": tokens,
            "weight_stream_bytes": weight_stream,
            "act_traffic_bytes": act_traffic,
            "cache_traffic_bytes": cache_traffic,
        },
    )
