"""Shared benchmark helpers: the three systems-under-test as model variants
plus a tiny CPU trainer for the accuracy experiments.

System variants (Table 1 / ablations), expressed through the framework's own
config knobs:

  * ``ec2moe``   — HL-GGN group gate (K groups) + low-rank dispatch
                   compression (eq. 8, trained jointly); hardware-aware
                   selection active at the end tier during serving.
  * ``brownout`` — BrownoutServe-style: flat gate (num_groups=1 degenerates
                   eq. 5-7 to a single softmax), full experts, no
                   compression.  Evaluated under network instability: each
                   expert is unavailable with probability p_net per batch
                   (timeout -> the router's mass renormalizes, paper §Acc).
  * ``edgemoe``  — end-only: flat gate + a STATIC 40% expert subset (the
                   memory-resident working set), train and eval.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CompressionConfig, get_config, smoke_config
from repro.configs.switch_base import with_experts
from repro.data.pipeline import DataConfig, batches, eval_accuracy
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.training.optimizer import OptimizerConfig, init_optimizer

SYSTEMS = ("ec2moe", "brownoutserve", "edgemoe")


def tiny_switch(num_experts: int, system: str, *, d_model=128, seq=64):
    """Smoke-scale switch-base variant for CPU accuracy runs."""
    cfg = smoke_config(with_experts(num_experts))
    moe = dataclasses.replace(
        cfg.moe,
        num_experts=num_experts,
        d_ff_expert=128,
        capacity_factor=2.0,
        num_groups=(max(2, num_experts // 4) if system == "ec2moe" else 1),
    )
    kw = dict(moe=moe, d_model=d_model, vocab_size=512)
    if system == "ec2moe":
        kw["compression"] = CompressionConfig(
            rank=d_model // 2, boundaries=("dispatch",), recon_weight=0.05
        )
    return cfg.replace(**kw)


def static_mask(num_experts: int, cap: float = 0.4) -> jnp.ndarray:
    n = max(1, int(np.floor(cap * num_experts)))
    return jnp.arange(num_experts) < n


def random_drop_mask(num_experts: int, p_drop: float, rng) -> jnp.ndarray:
    m = rng.random(num_experts) >= p_drop
    if not m.any():
        m[rng.integers(num_experts)] = True
    return jnp.asarray(m)


def train_tiny(
    cfg,
    data_cfg: DataConfig,
    *,
    steps: int = 300,
    batch_size: int = 16,
    lr: float = 3e-3,
    train_mask=None,
    seed: int = 0,
) -> Tuple[object, Dict]:
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = OptimizerConfig(name="adamw", lr=lr, warmup_steps=20, decay_steps=steps)
    opt_state = init_optimizer("adamw", params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    if train_mask is not None:
        loss_step = make_train_step(model, opt_cfg)  # re-closure w/ mask below
    last = {}
    for i, b in enumerate(batches(data_cfg, batch_size, steps, seed=seed + 1)):
        bj = {k: jnp.asarray(v) for k, v in b.items()}
        if train_mask is not None:
            # thread the expert mask through the loss (end-tier training)
            params, opt_state, last = _masked_step(
                model, opt_cfg, params, opt_state, bj, train_mask
            )
        else:
            params, opt_state, last = step_fn(params, opt_state, bj)
    # scalar metrics to floats; vector gate statistics (expert_frac /
    # group_frac, [E]/[K]) to lists
    host = jax.tree.map(
        lambda v: float(v) if np.ndim(v) == 0 else np.asarray(v).tolist(),
        last,
    )
    return model, {"params": params, "metrics": host}


_MASKED_CACHE = {}


def _masked_step(model, opt_cfg, params, opt_state, batch, mask):
    key = (id(model.cfg), model.cfg.name)
    if key not in _MASKED_CACHE:
        from repro.launch.steps import make_loss_fn
        from repro.training import optimizer as opt_mod

        loss_fn = make_loss_fn(model)

        @jax.jit
        def step(params, opt_state, batch, mask):
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, mask
            )
            grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.grad_clip)
            params, opt_state, lr = opt_mod.apply_optimizer(
                model.cfg.optimizer, opt_cfg, grads, opt_state, params
            )
            return params, opt_state, metrics

        _MASKED_CACHE[key] = step
    return _MASKED_CACHE[key](params, opt_state, batch, mask)


def eval_tiny(
    model,
    params,
    data_cfg: DataConfig,
    *,
    n_batches: int = 16,
    batch_size: int = 32,
    expert_mask=None,
    drop_p: float = 0.0,
    seed: int = 1234,
) -> float:
    rng = np.random.default_rng(seed)
    fwd = jax.jit(
        lambda p, b, m: model.train_logits(p, b, expert_mask=m, train=False)[0]
    )
    accs = []
    for b in batches(data_cfg, batch_size, n_batches, seed=seed):
        mask = expert_mask
        if drop_p > 0:
            mask = random_drop_mask(model.cfg.moe.num_experts, drop_p, rng)
            if expert_mask is not None:
                mask = mask & expert_mask
        logits = fwd(params, {"tokens": jnp.asarray(b["tokens"])}, mask)
        accs.append(eval_accuracy(np.asarray(logits), b["labels"]))
    return float(np.mean(accs))


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
