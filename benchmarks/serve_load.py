"""Production load benchmark: SLO-aware admission + preemption under a
seeded arrival process.

Drives a seeded mixed workload (mostly short interactive requests at
priority 0, a tail of long low-priority batch requests — the serving
regime EC2MoE targets) through the fleet engine on the modeled clock,
twice over the *same* arrival trace:

  * ``priority`` — SLO-class admission ordering + preemption: a running
    low-priority slot is spilled (paged-KV pages gathered out through the
    page tables) at a safe point when an interactive request is blocked,
    and restored later, token stream bit-identical.
  * ``fifo``     — pure submission order, no preemption (the seed's old
    behaviour): a long batch request at the head of the line blocks every
    interactive arrival behind it.

The claim measured: under a burst that oversubscribes the fleet, priority
admission keeps interactive p99 TTFT under the stated target while pure
FIFO — same trace, same fleet, same modeled costs — violates it.  Both
modes must finish every request (``dropped == 0``).  Tokens are computed
for real; stage times use ``timing="modeled"`` so the run is
deterministic: identical seeds reproduce identical arrival traces and
identical percentile metrics.

Report keys per mode/class: ``ttft_p50/p90/p99``, ``tpot_p50/p90/p99``,
``sustained_tok_s``, ``preemptions``, ``dropped``; plus the fleet fault
counters (all zero here — see ``benchmarks.serve_chaos`` for the run
that exercises them).

    PYTHONPATH=src python -m benchmarks.serve_load [--n-requests 1000]
        [--rate-rps R] [--arrival poisson|bursty] [--lanes N]
        [--ttft-target S] [--seed S] [--out bench_serve_load.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict

import jax

from repro.configs import get_config, smoke_config
from repro.models.model import build_model
from repro.serving.common import VirtualClock
from repro.serving.fleet import FleetServingEngine
from repro.serving.loadgen import (
    BATCH,
    INTERACTIVE,
    build_schedule,
    bursty_arrivals,
    drive,
    poisson_arrivals,
    summarize,
)

from benchmarks.fleet_throughput import CLOUD, FLEET_PROFILES
from benchmarks.serve_chaos import FAULT_KEYS


def _build_engine(model, params, *, n_lanes: int, max_batch: int,
                  admission: str, preemption: bool) -> FleetServingEngine:
    return FleetServingEngine(
        model, params,
        end_profiles=FLEET_PROFILES[:n_lanes],
        cloud_profile=CLOUD,
        cloud_servers=2,
        compression_rank=max(model.cfg.d_model // 4, 1),
        max_batch=max_batch, max_len=160,
        timing="modeled", max_spill=1.0,
        clock=VirtualClock(),
        admission=admission, preemption=preemption,
    )


def run(
    *,
    arch: str = "tinyllama-1.1b",
    num_layers: int = 2,
    n_requests: int = 1000,
    rate_rps: float = 0.0,  # 0 -> the calibrated oversubscription default
    arrival: str = "poisson",
    burst_factor: float = 8.0,
    n_lanes: int = 3,
    max_batch: int = 2,
    ttft_target_s: float = 0.2,
    warmup_frac: float = 0.05,
    seed: int = 0,
    assert_fifo_violates: bool = True,
) -> Dict:
    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    if rate_rps <= 0:
        # Calibrated oversubscription for the 3-lane smoke fleet: total
        # offered load (decode + batch prefill) exceeds the modeled service
        # rate so a FIFO queue grows with the trace, while the interactive
        # share alone fits comfortably — priority admission reaches a
        # steady state and its p99 TTFT stays flat in n.
        rate_rps = 800.0

    if arrival == "poisson":
        arrivals = poisson_arrivals(n_requests, rate_rps, seed)
    elif arrival == "bursty":
        arrivals = bursty_arrivals(
            n_requests, rate_rps, seed, burst_factor=burst_factor
        )
    else:
        raise ValueError(f"arrival={arrival!r}")
    warmup_s = float(arrivals[int(len(arrivals) * warmup_frac)])

    classes = (
        dataclasses.replace(INTERACTIVE, ttft_slo_s=ttft_target_s),
        BATCH,
    )

    modes = {}
    for mode, (admission, preemption) in (
        ("priority", ("priority", True)),
        ("fifo", ("fifo", False)),
    ):
        # Fresh engine AND fresh Request objects per mode: same seed, so
        # both modes replay byte-identical prompts on the same arrivals.
        schedule = build_schedule(arrivals, classes, seed + 1)
        eng = _build_engine(model, params, n_lanes=n_lanes,
                            max_batch=max_batch,
                            admission=admission, preemption=preemption)
        reqs = drive(eng, schedule)
        m = eng.metrics()
        row = {
            "all": summarize(reqs, warmup_s=warmup_s),
            "interactive": summarize(reqs, warmup_s=warmup_s, priority=0),
            "batch": summarize(
                reqs, warmup_s=warmup_s, priority=BATCH.priority
            ),
            "engine_preemptions": m["preemptions"],
            "engine_preempt_restores": m["preempt_restores"],
            "preempt_spill_bytes": m["preempt_spill_bytes"],
            # fault counters (serve_chaos.FAULT_KEYS): all zero on this
            # fault-free harness — their presence keeps the two load
            # benchmarks' report schemas aligned
            **{k: m[k] for k in FAULT_KEYS},
        }
        assert row["all"]["dropped"] == 0, (
            f"{mode}: dropped requests: {row['all']}"
        )
        modes[mode] = row
        inter = row["interactive"]
        print(
            f"[serve_load] {mode:8s} interactive ttft_p99={inter['ttft_p99']:.3f}s "
            f"tpot_p99={inter['tpot_p99']:.4f}s "
            f"tok/s={row['all']['sustained_tok_s']:.1f} "
            f"preempt={m['preemptions']} "
            f"(n={row['all']['n']} finished={row['all']['finished']})",
            flush=True,
        )

    p99_prio = modes["priority"]["interactive"]["ttft_p99"]
    p99_fifo = modes["fifo"]["interactive"]["ttft_p99"]
    assert p99_prio < ttft_target_s, (
        f"priority admission misses the interactive TTFT target: "
        f"p99={p99_prio:.3f}s target={ttft_target_s}s"
    )
    if assert_fifo_violates:
        assert p99_fifo > ttft_target_s, (
            f"FIFO unexpectedly meets the target (load too light to "
            f"discriminate): p99={p99_fifo:.3f}s target={ttft_target_s}s"
        )
    print(
        f"[serve_load] interactive ttft_p99: priority {p99_prio:.3f}s < "
        f"{ttft_target_s}s target < fifo {p99_fifo:.3f}s "
        f"({n_requests} requests, {arrival} arrivals @ {rate_rps:.1f} rps, "
        f"{n_lanes} lanes)",
        flush=True,
    )
    return {
        "arch": cfg.name,
        "n_requests": n_requests,
        "arrival": arrival,
        "rate_rps": rate_rps,
        "burst_factor": burst_factor if arrival == "bursty" else None,
        "n_lanes": n_lanes,
        "max_batch": max_batch,
        "cloud_servers": 2,
        "seed": seed,
        "warmup_s": round(warmup_s, 3),
        "ttft_target_s": ttft_target_s,
        "modes": modes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=1000)
    ap.add_argument("--rate-rps", type=float, default=0.0)
    ap.add_argument("--arrival", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--burst-factor", type=float, default=8.0)
    ap.add_argument("--lanes", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--ttft-target", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--num-layers", type=int, default=2)
    ap.add_argument("--no-assert-fifo-violates", action="store_true")
    ap.add_argument("--out", default="bench_serve_load.json")
    args = ap.parse_args()
    row = run(
        num_layers=args.num_layers,
        n_requests=args.n_requests,
        rate_rps=args.rate_rps,
        arrival=args.arrival,
        burst_factor=args.burst_factor,
        n_lanes=args.lanes,
        max_batch=args.max_batch,
        ttft_target_s=args.ttft_target,
        seed=args.seed,
        assert_fifo_violates=not args.no_assert_fifo_violates,
    )
    json.dump([row], open(args.out, "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
