"""Figures 5 + 6: throughput and end-to-end latency of the three systems
across expert counts (8/16/32/64), full-size Switch-Base, paper testbed:
a fleet of 10 Xeon end devices sharing 2xA100 cloud over 300 Mbps +-20%.

Fig 5 (throughput): saturation throughput — requests offered well above
capacity; the completion rate is the system's capacity.  EC2MoE plans its
split throughput-optimally (route-aware, no load headroom to spare).

Fig 6 (latency): mean end-to-end latency at a loaded operating point
(8 req/s); EC2MoE's route-aware scheduler plans latency-optimally within
the feasible-capacity set (the paper's "dynamic workload" adaptation).
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.configs.switch_base import with_experts
from repro.sim.policies import PolicyConfig, make_requests
from repro.sim.simulator import Link, poisson_arrivals, simulate

from benchmarks.common import SYSTEMS


def run(
    expert_counts=(8, 16, 32, 64),
    saturation_rps: float = 60.0,
    operating_rps: float = 9.0,
    n_requests: int = 600,
    fluctuation: float = 0.2,
    seed: int = 0,
) -> List[Dict]:
    rows = []
    pc = PolicyConfig()
    for E in expert_counts:
        cfg = with_experts(E)
        arr_sat = poisson_arrivals(saturation_rps, n_requests, seed)
        arr_op = poisson_arrivals(operating_rps, n_requests // 2, seed + 1)
        for system in SYSTEMS:
            m_sat = simulate(
                make_requests(system, cfg, pc, arr_sat, offered_rps=0.0),
                link=Link(0.3, fluctuation=fluctuation, seed=seed),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            m_op = simulate(
                make_requests(system, cfg, pc, arr_op, offered_rps=operating_rps),
                link=Link(0.3, fluctuation=fluctuation, seed=seed + 1),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            rows.append(
                dict(
                    experts=E,
                    system=system,
                    throughput_rps=round(m_sat["throughput_rps"], 3),
                    latency_s=round(m_op["latency_mean_s"], 4),
                    latency_p95_s=round(m_op["latency_p95_s"], 4),
                )
            )
            print(
                f"[fig5/6] E={E} {system}: {m_sat['throughput_rps']:.2f} req/s "
                f"(saturation), lat@{operating_rps:g}rps "
                f"{m_op['latency_mean_s']*1e3:.0f} ms", flush=True,
            )
    return rows


def summarize(rows: List[Dict]) -> Dict[str, float]:
    """Paper-claim ratios: EC2MoE vs baselines (throughput x, latency %)."""
    import numpy as np

    def col(system, key):
        return np.array([r[key] for r in rows if r["system"] == system])

    out = {}
    for base in ("brownoutserve", "edgemoe"):
        out[f"throughput_x_vs_{base}"] = float(
            (col("ec2moe", "throughput_rps") / col(base, "throughput_rps")).mean()
        )
        out[f"latency_reduction_vs_{base}"] = float(
            (1 - col("ec2moe", "latency_s") / col(base, "latency_s")).mean()
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_fig5_6.json")
    args = ap.parse_args()
    rows = run()
    s = summarize(rows)
    print("[fig5/6] summary:", {k: round(v, 3) for k, v in s.items()})
    print("[fig5/6] paper claims: throughput 2.2x (vs cloud) / 5.1x (vs edge); "
          "latency -67% (vs cloud) / -53% (vs edge)")
    json.dump({"rows": rows, "summary": s}, open(args.out, "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
