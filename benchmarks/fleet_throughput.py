"""Fleet serving benchmark: aggregate throughput vs number of end devices.

Serves one fixed request workload through the heterogeneous multi-end
fleet engine (``serving.fleet.FleetServingEngine``) with 1..N end devices
— including one deliberate straggler (weak compute, slow link) — against
one shared cloud tier, and reports the modeled aggregate decode rate
(``aggregate_tokens_per_s``: total generated tokens over the fleet-wide
resource-occupancy makespan, the same queueing model as
``sim.simulator``).  The paper's scalability claim at serving level:

    for a fixed offered workload, aggregate tokens/s grows monotonically
    as end devices are added — route-aware placement spreads requests over
    the new device's end+link stages, the shared cloud being the only
    contended resource,

and the fleet degrades *gracefully* under per-device drift: phase 2 cuts
one device's bandwidth mid-run — only that device replans (at its own
drained safe point, recorded in ``replan_events``, landing on a
compressed interior split) and every request still completes (no stall).

Phase 3 exercises the paged expert-weight pool at fleet scale on an MoE
model: one lane's memory budget halves mid-run — its slab capacity
follows, its resident expert set shrinks via EVICTIONS at that lane's own
safe point (every end layer keeps at least one resident), and the fleet
keeps serving: every request completes, no other lane evicts or replans,
aggregate tok/s stays positive.

Phase 4 exercises the fleet-wide expert store under skewed routing: two
lanes' measured traffic drifts to *overlapping* expert groups, so one
lane's slab misses are served from the peer that already fetched them —
over the modeled end<->end LAN, booked on BOTH lanes' link timelines —
while the divergent remainder keeps fleet-wide unique residency well
above any single lane's slab capacity, and the peer-served slabs come
off the cloud downlink (strictly fewer ``expert_bytes_down`` than the
isolated-pools baseline on the same trace).

Tokens are computed for real; stage times use ``timing="modeled"`` (the
planner's capability cost model) because one host cannot exhibit four
declared device speeds — which also makes the run deterministic.

    PYTHONPATH=src python -m benchmarks.fleet_throughput [--out bench_fleet.json]
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.hardware import DeviceProfile
from repro.models.model import build_model
from repro.serving.common import Request
from repro.serving.fleet import FleetServingEngine

# Smoke-scale fleet: three device classes plus one straggler, against a
# deliberately *scarce* shared cloud (the fleet regime the paper's
# scalability claim lives in).  Calibrated so the per-device planners put
# real compute on the end tiers — strong/mid devices plan end-heavy (often
# all-end) splits against their 1/N cloud share, the straggler plans
# cloud-heavy — because throughput can only scale with devices if the
# added devices' end resources carry work.
FLEET_PROFILES = [
    DeviceProfile("end-strong", peak_gflops=8.0, mem_gb=16.0,
                  mem_bw_gbs=100.0, net_gbps=2.0),
    DeviceProfile("end-mid", peak_gflops=6.0, mem_gb=8.0,
                  mem_bw_gbs=50.0, net_gbps=1.0),
    DeviceProfile("end-mid", peak_gflops=6.0, mem_gb=8.0,
                  mem_bw_gbs=50.0, net_gbps=1.0),
    DeviceProfile("end-straggler", peak_gflops=2.0, mem_gb=4.0,
                  mem_bw_gbs=25.0, net_gbps=0.25),
]
CLOUD = DeviceProfile("cloud-sim", peak_gflops=4.0, mem_gb=80.0,
                      mem_bw_gbs=500.0, net_gbps=2.0)


def _requests(n: int, max_new_tokens: int, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(i, rng.integers(0, 500, size=int(rng.integers(8, 24))).astype(np.int32),
                max_new_tokens=max_new_tokens)
        for i in range(n)
    ]


def run(
    *,
    arch: str = "tinyllama-1.1b",
    num_layers: int = 4,
    n_requests: int = 48,
    max_new_tokens: int = 16,
    max_batch: int = 2,
    cloud_servers: int = 1,
    seed: int = 0,
    # Tight spill guard: chunked prefill books prompt compute on the
    # timeline honestly, so handing the straggler more work than its
    # end-tier prefill time is worth would sink the n=4 scaling point
    # (the seed's looser default guard predates prefill accounting).
    max_spill: float = 1.0,
) -> Dict:
    cfg = smoke_config(get_config(arch)).replace(num_layers=num_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rank = max(cfg.d_model // 4, 1)  # eq. 8 boundary codec (interior splits)

    n_max = len(FLEET_PROFILES)
    scaling = []
    for n in range(1, n_max + 1):
        eng = FleetServingEngine(
            model, params,
            end_profiles=FLEET_PROFILES[:n],
            cloud_profile=CLOUD,
            cloud_servers=cloud_servers,
            compression_rank=rank,
            max_batch=max_batch, max_len=128,
            timing="modeled", max_spill=max_spill,
        )
        for r in _requests(n_requests, max_new_tokens, seed):
            eng.submit(r)
        done = eng.run()
        m = eng.metrics()
        assert len(done) == n_requests, (len(done), n)
        placed = [0] * n
        for ev in eng.placed:
            placed[ev["device"]] += 1
        assert m["kv_pages_in_use"] == 0, "pages leaked after drain"
        scaling.append({
            "n_devices": n,
            "splits": m["splits"],
            "requests_per_device": placed,
            "tokens": m["tokens"],
            "fleet_makespan_s": round(m["fleet_makespan_s"], 4),
            "aggregate_tokens_per_s": round(m["aggregate_tokens_per_s"], 2),
            # fleet-wide paged KV: per-lane end pools + one shared cloud pool
            "kv_pages_capacity": m["kv_pages_capacity"],
            "kv_bytes_peak": m["kv_bytes_peak"],
        })
        print(
            f"[fleet_throughput] n={n} splits={m['splits']} placed={placed} "
            f"tokens={m['tokens']} "
            f"agg={m['aggregate_tokens_per_s']:.1f} tok/s "
            f"kv_peak={m['kv_bytes_peak']/1024:.1f}KiB",
            flush=True,
        )

    rates = [row["aggregate_tokens_per_s"] for row in scaling]
    for a, b in zip(rates, rates[1:]):
        assert b > a, f"fleet throughput must scale with devices: {rates}"

    # -- phase 2: cut one device's bandwidth mid-run (fig. 8 dynamics at
    # -- fleet scale) — only that device replans; nothing stalls ------------
    eng = FleetServingEngine(
        model, params,
        end_profiles=FLEET_PROFILES,
        cloud_profile=CLOUD,
        cloud_servers=cloud_servers,
        compression_rank=rank,
        max_batch=max_batch, max_len=128,
        timing="modeled", max_spill=max_spill,
    )
    # Cut a lane serving an *edge* split (boundary shipped uncompressed —
    # the codec only applies interior): once the wire cost dwarfs compute,
    # the replanner moves that lane to a compressed interior split.  Lanes
    # already on interior compressed splits are bandwidth-stable by design
    # (wire cost is split-independent there; see benchmarks.decode_pipeline).
    R = cfg.block_repeat
    cut_dev = next(i for i, l in enumerate(eng.lanes) if l.split in (0, R))
    for r in _requests(n_requests, max_new_tokens, seed + 1):
        eng.submit(r)
    for _ in range(4):
        eng.step()
    lane = eng.lanes[cut_dev]
    old_split = lane.split
    # self-calibrating cut: make the uncompressed boundary ~40x the lane's
    # modeled bottleneck stage, so the compressed-interior plan clears the
    # replan hysteresis by construction
    t_ref = max(lane.plan.est_step_time_s, 1e-9)
    gbps_cut = lane.tiers.boundary_bytes * 8.0 / (40.0 * t_ref) / 1e9
    eng.observe_bandwidth(cut_dev, gbps_cut)
    done = eng.run()
    m2 = eng.metrics()
    events = eng.replan_events
    assert len(done) == n_requests, "bandwidth cut stalled the fleet"
    assert any(ev["device"] == cut_dev for ev in events), (
        "bandwidth cut must trigger a replan on the cut device"
    )
    assert all(ev["device"] == cut_dev for ev in events), (
        "only the drifted device may replan"
    )
    assert 0 < eng.lanes[cut_dev].split < R and eng.lanes[cut_dev].tiers.compress, (
        "cut lane should land on a compressed interior split"
    )

    # -- phase 3: paged expert weights under a per-lane memory cut (MoE
    # -- model) — one lane's slab budget halves, its resident set shrinks
    # -- via evictions, nothing else stalls ----------------------------------
    expert_row = _run_expert_memory_cut(
        n_requests=max(n_requests // 2, 8),
        max_new_tokens=max_new_tokens,
        max_batch=max_batch,
        cloud_servers=cloud_servers,
        max_spill=max_spill,
        seed=seed,
    )

    # -- phase 4: fleet expert store — skewed routes, peer slab fetch,
    # -- fleet-wide de-duplicated residency ----------------------------------
    fleet_store_row = _run_fleet_expert_store(
        n_requests=max(n_requests // 4, 8),
        max_new_tokens=max_new_tokens,
        max_batch=max_batch,
        cloud_servers=cloud_servers,
        max_spill=max_spill,
        seed=seed,
    )

    # -- phase 5: quantized byte streams at fleet scale — the same MoE
    # -- trace with the int8 codecs off and on; boundary wire, KV pages,
    # -- and re-admitted expert slabs must each shrink ~2x ------------------
    quant_row = _run_quant_fleet(
        n_requests=max(n_requests // 4, 8),
        max_new_tokens=max_new_tokens,
        max_batch=max_batch,
        cloud_servers=cloud_servers,
        max_spill=max_spill,
        seed=seed,
    )

    row = {
        "arch": cfg.name,
        "block_repeat": cfg.block_repeat,
        "cloud_servers": cloud_servers,
        "compression_rank": rank,
        "scaling": scaling,
        "expert_memory_cut": expert_row,
        "fleet_expert_store": fleet_store_row,
        "quantized_streams": quant_row,
        "bandwidth_cut": {
            "device": cut_dev,
            "gbps_cut": gbps_cut,
            "replan_events": events,
            "splits_after": m2["splits"],
            "aggregate_tokens_per_s": round(m2["aggregate_tokens_per_s"], 2),
            # peak only: the fleet is drained here, so instantaneous
            # in-use/utilization would always read zero
            "kv_bytes_peak": m2["kv_bytes_peak"],
        },
    }
    print(
        f"[fleet_throughput] dev{cut_dev} bw cut {gbps_cut:.2e} gbps -> "
        f"{len(events)} replan(s), split {old_split}->{eng.lanes[cut_dev].split}, "
        f"splits {m2['splits']}, agg={m2['aggregate_tokens_per_s']:.1f} tok/s "
        f"(all requests done)",
        flush=True,
    )
    return row


def _run_expert_memory_cut(
    *,
    n_requests: int,
    max_new_tokens: int,
    max_batch: int,
    cloud_servers: int,
    max_spill: float,
    seed: int,
) -> Dict:
    from repro.core.expertpool import expert_slab_bytes
    from repro.core.hardware import DeviceState

    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    slab = expert_slab_bytes(cfg)
    cap_n = max(1, int(cfg.moe.local_selection_cap * cfg.moe.num_experts))
    n_pos = sum(1 for s in cfg.layer_pattern if s.moe)

    def build(mems, force_splits=None):
        profiles = [
            DeviceProfile(f"end-moe{i}", peak_gflops=p.peak_gflops,
                          mem_gb=mems[i], mem_bw_gbs=p.mem_bw_gbs,
                          net_gbps=p.net_gbps)
            for i, p in enumerate(FLEET_PROFILES[:2])
        ]
        return FleetServingEngine(
            model, params,
            end_profiles=profiles, cloud_profile=CLOUD,
            cloud_servers=cloud_servers,
            max_batch=max_batch, max_len=128,
            timing="modeled", max_spill=max_spill,
            force_splits=force_splits,
        )

    # probe pass: memory never enters the split search, so the planner's
    # splits with generous memory ARE the optima — pin them in the real
    # pass so mid-run mask rechecks cannot move a tier boundary and the
    # memory cut exercises only the expert pool
    splits = [lane.split for lane in build([1.0, 1.0]).lanes]
    # lane memory sized so the full-state slab budget exactly covers each
    # lane's target expert set, and a mem_free=0.5 state halves it
    mems = [2 * max(s, 1) * n_pos * cap_n * slab / 1e9 for s in splits]
    eng = build(mems, force_splits=splits)

    for r in _requests(n_requests, max_new_tokens, seed + 2):
        eng.submit(r)
    for _ in range(4):
        eng.step()
    cut = 1
    lane = eng.lanes[cut]
    slabs_before = lane.expert_pool.slabs_in_use
    eng.update_device_state(cut, DeviceState(mem_free=0.5))
    done = eng.run()
    m = eng.metrics()

    assert len(done) == n_requests, "memory cut stalled the fleet"
    assert lane.n_expert_evictions > 0, "halved budget must evict slabs"
    assert lane.expert_pool.capacity < slabs_before
    assert lane.expert_pool.peak_in_use == slabs_before
    for lid in lane._active_lids():
        assert lane.expert_pool.resident_count(lid) >= 1
    other = eng.lanes[1 - cut]
    assert other.n_expert_evictions == 0, "only the cut lane may evict"
    assert not any(
        ev["mask_changed"] for ev in other.replan_events
    ), "only the cut lane's expert set may change"
    assert [lane.split for lane in eng.lanes] == splits, (
        "the memory cut must not move a tier boundary"
    )
    assert m["aggregate_tokens_per_s"] > 0

    row = {
        "splits": splits,
        "cut_device": cut,
        "slabs_before": slabs_before,
        "slabs_after": lane.expert_pool.slabs_in_use,
        "capacity_after": lane.expert_pool.capacity,
        "evictions": lane.n_expert_evictions,
        "fleet_hit_rate": round(m["expert_hit_rate"], 4),
        "aggregate_tokens_per_s": round(m["aggregate_tokens_per_s"], 2),
    }
    print(
        f"[fleet_throughput] dev{cut} mem halved -> slabs "
        f"{slabs_before}->{row['slabs_after']} "
        f"(capacity {row['capacity_after']}, {row['evictions']} evictions), "
        f"splits {splits} unchanged, "
        f"agg={row['aggregate_tokens_per_s']:.1f} tok/s (all requests done)",
        flush=True,
    )
    return row


def _run_fleet_expert_store(
    *,
    n_requests: int,
    max_new_tokens: int,
    max_batch: int,
    cloud_servers: int,
    max_spill: float,
    seed: int,
) -> Dict:
    """Skewed-route fleet on an MoE model: lane 0's traffic drifts to
    groups {2,3}, lane 1's to {1,2}.  Lane 1's misses on the shared group
    2 experts are served from lane 0 over the modeled end<->end LAN; the
    divergent remainder keeps the fleet-wide unique resident set >= 1.5x
    any single lane's slab capacity."""
    from repro.core.expertpool import expert_slab_bytes
    from repro.core.hardware import DeviceState

    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    slab = expert_slab_bytes(cfg)
    E, K = cfg.moe.num_experts, cfg.moe.num_groups
    Mk = E // K
    cap_n = max(1, int(cfg.moe.local_selection_cap * E))
    n_pos = sum(1 for s in cfg.layer_pattern if s.moe)

    def build(mems, force_splits=None, expert_fleet=True):
        profiles = [
            DeviceProfile(f"end-moe{i}", peak_gflops=p.peak_gflops,
                          mem_gb=mems[i], mem_bw_gbs=p.mem_bw_gbs,
                          net_gbps=p.net_gbps)
            for i, p in enumerate(FLEET_PROFILES[:2])
        ]
        return FleetServingEngine(
            model, params,
            end_profiles=profiles, cloud_profile=CLOUD,
            cloud_servers=cloud_servers,
            max_batch=max_batch, max_len=128,
            timing="modeled", max_spill=max_spill,
            force_splits=force_splits, expert_fleet=expert_fleet,
            expert_peer_gbps=25.0,  # fleet LAN >> either WAN uplink
            expert_prefetch_per_tick=4, preemption=False,
        )

    # probe pass: the pinned splits must be the planner's own optima —
    # a device-state update re-runs the split search, and a boundary move
    # would re-base layer ids and instant-fill entering blocks (phase 3's
    # pattern; memory never enters the split search so generous probe
    # memory finds the same splits)
    splits = [lane.split for lane in build([1.0, 1.0]).lanes]
    # lane memory sized so the slab budget exactly covers each lane's
    # target expert set: divergent masks then cannot hide behind slack
    # capacity — residency must actually swap via evictions
    mems = [2 * max(s, 1) * n_pos * cap_n * slab / 1e9 for s in splits]

    # measured traffic skew, injected as the engines' EMA state: group
    # frequencies steer the eq. 4 admit, expert frequencies clear the
    # registry's 1/E replication bar for the experts each lane re-admits
    # 0.8/0.2: the gap must exceed the bounded group-cost term (0.5 after
    # normalization), or the registry's cheap-to-place signal would
    # reorder the admit toward the peer-resident group and shrink the
    # divergence this scenario is built to show
    def skew(groups):
        gf = np.zeros(K)
        gf[groups[0]], gf[groups[1]] = 0.8, 0.2
        mask_e = [g * Mk + j for g in groups for j in range(Mk)]
        ef = np.zeros(E)
        ef[mask_e] = 1.0 / len(mask_e)
        return gf, ef

    def drive(expert_fleet):
        eng = build(mems, force_splits=splits, expert_fleet=expert_fleet)
        for r in _requests(n_requests, max_new_tokens, seed + 3):
            eng.submit(r)
        for _ in range(2):
            eng.step()
        # lane 0 drifts first: groups {2,3} — every re-admitted slab comes
        # from the cloud (no peer holds them yet)
        gf, ef = skew((2, 3))
        eng.lanes[0]._group_freq, eng.lanes[0]._route_freq = gf, ef
        eng.update_device_state(0, DeviceState())
        for _ in range(8):
            eng.step()
        # lane 1 follows onto overlapping groups {1,2}: its misses on the
        # shared group-2 experts are now peer-resident on lane 0
        gf, ef = skew((1, 2))
        eng.lanes[1]._group_freq, eng.lanes[1]._route_freq = gf, ef
        eng.update_device_state(1, DeviceState())
        done = eng.run()
        assert len(done) == n_requests, "expert-store phase stalled the fleet"
        return eng

    eng = drive(expert_fleet=True)
    iso = drive(expert_fleet=False)
    m, mi = eng.metrics(), iso.metrics()
    reg = eng.expert_registry

    # peer fetch happened, and every transfer flowed lane 0 -> lane 1
    assert m["expert_peer_fetches"] > 0, "no slab was served from a peer"
    assert all((s, d) == (0, 1) for s, d, _ in reg.peer_bookings)
    # both ends of each peer transfer ride the fleet timeline: a lane's
    # link busy time is its own boundary/prefill/slab traffic plus the
    # peer seconds it served as a source
    for i, lane in enumerate(eng.lanes):
        peer_out = sum(t for s, _d, t in reg.peer_bookings if s == i)
        own = (lane._stage_busy["link"] + lane._prefill_busy["link"]
               + lane.expert_wire_s)
        assert abs(eng.timeline.busy_s[f"link{i}"] - (own + peer_out)) < 1e-9
    # divergent masks: fleet-wide unique residency beats any single lane's
    # slab capacity by >= 1.5x, yet the shared experts are still held once
    # per interested lane (unique < summed residents)
    unique = m["expert_unique_residents"]
    max_cap = max(lane.expert_pool.capacity for lane in eng.lanes)
    assert unique >= 1.5 * max_cap, (unique, max_cap)
    assert unique < m["expert_resident_slabs"]
    # the peer-served slabs came off the cloud downlink: strictly fewer
    # cloud bytes than the isolated-pools baseline on the SAME trace
    assert mi["expert_peer_fetches"] == 0
    assert m["expert_bytes_down"] < mi["expert_bytes_down"], (
        m["expert_bytes_down"], mi["expert_bytes_down"]
    )
    assert m["aggregate_tokens_per_s"] > 0

    row = {
        "splits": splits,
        "unique_residents": unique,
        "resident_slabs": m["expert_resident_slabs"],
        "dedup_ratio": round(m["expert_fleet_dedup_ratio"], 4),
        "max_lane_capacity": max_cap,
        "peer_fetches": m["expert_peer_fetches"],
        "bytes_peer": m["expert_bytes_peer"],
        "bytes_down": m["expert_bytes_down"],
        "bytes_down_isolated": mi["expert_bytes_down"],
        "fleet_hit_rate": round(m["expert_hit_rate"], 4),
        "aggregate_tokens_per_s": round(m["aggregate_tokens_per_s"], 2),
    }
    print(
        f"[fleet_throughput] fleet expert store: "
        f"{row['peer_fetches']} peer fetch(es) "
        f"({row['bytes_peer']/1024:.0f}KiB off the cloud downlink, "
        f"down {row['bytes_down']/1024:.0f}KiB vs "
        f"{row['bytes_down_isolated']/1024:.0f}KiB isolated), "
        f"unique residents {unique} vs lane capacity {max_cap} "
        f"(dedup ratio {row['dedup_ratio']}), "
        f"agg={row['aggregate_tokens_per_s']:.1f} tok/s (all requests done)",
        flush=True,
    )
    return row


def _run_quant_fleet(
    *,
    n_requests: int,
    max_new_tokens: int,
    max_batch: int,
    cloud_servers: int,
    max_spill: float,
    seed: int,
) -> Dict:
    """Quantized byte streams at fleet scale: the same skewed-route MoE
    trace with the int8 codecs off and on.  Boundary wire, KV page bytes,
    and re-admitted expert slabs (priced by the fleet registry at the
    STORED slab size) must each land at <= 0.55x the f32-path run; page
    and slab capacity must be >= 1.9x at the same memory budget."""
    from repro.core.expertpool import expert_slab_bytes
    from repro.core.hardware import DeviceState

    cfg = smoke_config(get_config("llama4-scout-17b-16e")).replace(num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    E, K = cfg.moe.num_experts, cfg.moe.num_groups
    Mk = E // K
    cap_n = max(1, int(cfg.moe.local_selection_cap * E))
    n_pos = sum(1 for s in cfg.layer_pattern if s.moe)

    def build(mems, force_splits=None, quant=False):
        profiles = [
            DeviceProfile(f"end-moe{i}", peak_gflops=p.peak_gflops,
                          mem_gb=mems[i], mem_bw_gbs=p.mem_bw_gbs,
                          net_gbps=p.net_gbps)
            for i, p in enumerate(FLEET_PROFILES[:2])
        ]
        return FleetServingEngine(
            model, params,
            end_profiles=profiles, cloud_profile=CLOUD,
            cloud_servers=cloud_servers,
            max_batch=max_batch, max_len=128,
            timing="modeled", max_spill=max_spill,
            force_splits=force_splits, expert_fleet=True,
            expert_prefetch_per_tick=4, preemption=False,
            quantize_kv=quant, quantize_experts=quant,
            quantize_boundary=quant,
        )

    # pin the planner's own optima (phase 3/4's pattern), probed with the
    # codecs off: the quantized run must serve the identical tier layout,
    # or the byte ratios would conflate codec gains with a split move
    splits = [lane.split for lane in build([1.0, 1.0]).lanes]

    # one drifted lane re-admits groups {2,3} mid-run: every re-admitted
    # slab crosses the cloud downlink, metered at the stored slab size
    def drive(quant):
        # budget sized in the run's own stored slab size -> both runs hold
        # the same slab COUNT and the wire ratio isolates bytes/slab
        slab = expert_slab_bytes(cfg, quantized=quant)
        mems = [2 * max(s, 1) * n_pos * cap_n * slab / 1e9 for s in splits]
        eng = build(mems, force_splits=splits, quant=quant)
        for r in _requests(n_requests, max_new_tokens, seed + 4):
            eng.submit(r)
        for _ in range(2):
            eng.step()
        gf = np.zeros(K)
        gf[2], gf[3] = 0.8, 0.2
        ef = np.zeros(E)
        mask_e = [g * Mk + j for g in (2, 3) for j in range(Mk)]
        ef[mask_e] = 1.0 / len(mask_e)
        eng.lanes[0]._group_freq, eng.lanes[0]._route_freq = gf, ef
        eng.update_device_state(0, DeviceState())
        done = eng.run()
        assert len(done) == n_requests, "quant fleet phase stalled"
        return eng

    ref = drive(quant=False)
    q = drive(quant=True)
    m_ref, m_q = ref.metrics(), q.metrics()

    up_ref = sum(l.link.bytes_up for l in ref.lanes)
    up_q = sum(l.link.bytes_up for l in q.lanes)
    up_ratio = up_q / max(up_ref, 1)
    assert 0 < up_ratio <= 0.55, f"fleet boundary bytes ratio {up_ratio}"
    # expert slab wire (cloud downlink), priced by the registry at the
    # stored slab size on the SAME re-admit trace
    assert m_ref["expert_bytes_down"] > 0 and m_q["expert_bytes_down"] > 0
    down_ratio = m_q["expert_bytes_down"] / m_ref["expert_bytes_down"]
    assert down_ratio <= 0.55, f"fleet expert wire ratio {down_ratio}"
    # per-lane paged-KV and slab capacity at the same memory budget
    for lane in q.lanes:
        kv = lane.kv_metrics()
        assert kv["kv_capacity_ratio"] >= 1.9, kv["kv_capacity_ratio"]
        em = lane.metrics()
        assert em["expert_capacity_ratio"] >= 1.9, em["expert_capacity_ratio"]
    for lane in ref.lanes:
        assert lane.kv_metrics()["kv_capacity_ratio"] == 1.0

    row = {
        "splits": splits,
        "boundary_bytes_up": up_q,
        "boundary_bytes_up_f32path": up_ref,
        "boundary_bytes_ratio": round(up_ratio, 4),
        "expert_bytes_down": m_q["expert_bytes_down"],
        "expert_bytes_down_f32path": m_ref["expert_bytes_down"],
        "expert_bytes_ratio": round(down_ratio, 4),
        "kv_capacity_ratio": round(
            min(l.kv_metrics()["kv_capacity_ratio"] for l in q.lanes), 4
        ),
        "aggregate_tokens_per_s": round(m_q["aggregate_tokens_per_s"], 2),
    }
    print(
        f"[fleet_throughput] quantized streams: boundary "
        f"x{row['boundary_bytes_ratio']} "
        f"({up_q/1024:.0f}KiB vs {up_ref/1024:.0f}KiB), expert wire "
        f"x{row['expert_bytes_ratio']} "
        f"({row['expert_bytes_down']/1024:.0f}KiB vs "
        f"{row['expert_bytes_down_f32path']/1024:.0f}KiB), "
        f"kv capacity x{row['kv_capacity_ratio']}, "
        f"agg={row['aggregate_tokens_per_s']:.1f} tok/s (all requests done)",
        flush=True,
    )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--n-requests", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    row = run(n_requests=args.n_requests, max_new_tokens=args.new_tokens)
    json.dump([row], open(args.out, "w"), indent=1)
    # stable machine-readable artifact name for CI collection, regardless
    # of --out
    if args.out != "BENCH_fleet.json":
        json.dump([row], open("BENCH_fleet.json", "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
