"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV — us_per_call is the wall time of
one harness invocation; ``derived`` is the headline metric that maps onto
the paper's claim for that table/figure.

  table1    accuracy gap EC2MoE - EdgeMoE (pp; paper: ~+4.1)
  fig5      EC2MoE saturation throughput multiple vs BrownoutServe (paper 2.2x)
  fig6      EC2MoE latency reduction vs BrownoutServe at the loaded
            operating point (paper -67%)
  fig7      EC2MoE throughput at 10 req/s offered (paper: linear scaling)
  fig8      EC2MoE throughput retention at 40% bandwidth fluctuation
  ablation  -PO-ECC throughput drop (paper -38%)
  roofline  mean roofline fraction over all dry-run cells (single pod)

Full sweeps with JSON outputs: run the individual modules
(``python -m benchmarks.table1_accuracy`` etc.).
"""

from __future__ import annotations

import json
import os
import time


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def bench_table1():
    from benchmarks.table1_accuracy import run

    rows = run(expert_counts=(8,), datasets=("glue_proxy",), steps=150)
    by = {r["system"]: r["accuracy"] for r in rows}
    return by["ec2moe"] - by["edgemoe"]


def bench_fig5():
    from benchmarks.fig5_6_perf import run, summarize

    rows = run(expert_counts=(16,), n_requests=300)
    return summarize(rows)["throughput_x_vs_brownoutserve"]


def bench_fig6():
    from benchmarks.fig5_6_perf import run, summarize

    rows = run(expert_counts=(16,), n_requests=300)
    return summarize(rows)["latency_reduction_vs_brownoutserve"]


def bench_fig7():
    from benchmarks.fig7_load import run

    rows = run(rates=(10,), n_requests=150)
    return next(r["throughput_rps"] for r in rows if r["system"] == "ec2moe")


def bench_fig8():
    from benchmarks.fig8_bandwidth import run

    rows = run(flucts=(0.0, 0.4), n_requests=150)
    t0 = next(r["throughput_rps"] for r in rows
              if r["system"] == "ec2moe" and r["fluctuation"] == 0.0)
    t4 = next(r["throughput_rps"] for r in rows
              if r["system"] == "ec2moe" and r["fluctuation"] == 0.4)
    return t4 / t0


def bench_ablation():
    from benchmarks.ablation import perf_ablation

    return perf_ablation(n=150)["throughput_drop_no_poecc_pct"]


def bench_roofline():
    from benchmarks.roofline import analyze

    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")
    if not os.path.exists(path):
        return float("nan")
    rows = analyze(json.load(open(path)))
    ok = [r for r in rows if r.get("status") == "ok" and r["mesh"] == "single"]
    return sum(r["roofline_fraction"] for r in ok) / max(len(ok), 1)


BENCHES = {
    "table1_accuracy_gap_pp": bench_table1,
    "fig5_throughput_x_vs_cloud": bench_fig5,
    "fig6_latency_reduction_vs_cloud": bench_fig6,
    "fig7_throughput_at_10rps": bench_fig7,
    "fig8_tput_retention_at_40pct_fluct": bench_fig8,
    "ablation_no_poecc_tput_drop_pct": bench_ablation,
    "roofline_mean_fraction_single_pod": bench_roofline,
}


def main() -> None:
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        try:
            val, us = _timed(fn)
            print(f"{name},{us:.0f},{val:.4f}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
