"""Figure 8: robustness to bandwidth fluctuation (0..40%)."""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.configs.switch_base import with_experts
from repro.sim.policies import PolicyConfig, make_requests
from repro.sim.simulator import Link, poisson_arrivals, simulate

from benchmarks.common import SYSTEMS


def run(flucts=(0.0, 0.1, 0.2, 0.3, 0.4), experts: int = 16,
        rate_rps: float = 6.0, n_requests: int = 240, seed: int = 0):
    rows: List[Dict] = []
    cfg = with_experts(experts)
    pc = PolicyConfig()
    arrivals = poisson_arrivals(rate_rps, n_requests, seed)
    for fl in flucts:
        for system in SYSTEMS:
            m = simulate(
                make_requests(system, cfg, pc, arrivals, offered_rps=rate_rps),
                link=Link(0.3, fluctuation=fl, seed=seed),
                end_servers=pc.n_end_devices, cloud_servers=pc.n_cloud_gpus,
            )
            rows.append(
                dict(fluctuation=fl, system=system,
                     throughput_rps=round(m["throughput_rps"], 3),
                     latency_mean_s=round(m["latency_mean_s"], 4))
            )
            print(f"[fig8] fluct={fl:.0%} {system}: "
                  f"tput={m['throughput_rps']:.2f} "
                  f"lat={m['latency_mean_s']*1e3:.0f}ms", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_fig8.json")
    args = ap.parse_args()
    json.dump(run(), open(args.out, "w"), indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
