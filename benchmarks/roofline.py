"""Roofline analysis (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms:

    compute    = FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HBM_bytes_per_device / HBM_bw           (819 GB/s)
    collective = wire_bytes_per_device / link_bw         (~50 GB/s/link)

FLOPs/HBM come from the analytic model (benchmarks.flops_model — XLA's
cost_analysis undercounts loop bodies, see EXPERIMENTS.md); collective
bytes come from the trip-count-corrected HLO parse stored by the dry-run.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--json dryrun_results.json]
Emits a markdown table + roofline_table.json for EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.configs import SHAPE_BY_NAME, get_config
from benchmarks.flops_model import cell_cost

PEAK_FLOPS = 197e12  # bf16 per chip (v5e)
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link


def analyze(records: List[Dict]) -> List[Dict]:
    out = []
    for r in records:
        if r.get("status") != "ok":
            out.append(dict(r))
            continue
        cfg = get_config(r["arch"])
        cell = SHAPE_BY_NAME[r["shape"]]
        n_dev = r["devices"]
        policy = (
            cfg.mesh_policy if cell.mode == "train" else cfg.serve_mesh_policy
        )
        # batch-sharding degree under the cell's mesh policy
        dp = n_dev if policy in ("fsdp", "dp") else n_dev // 16
        dp = min(dp, cell.global_batch) or 1
        cost = cell_cost(cfg, cell, n_dev, dp)
        t_comp = cost.flops / PEAK_FLOPS
        t_mem = cost.hbm_bytes / HBM_BW
        # bf16-equivalent: XLA:CPU promotes bf16 math/collectives to f32;
        # the TPU target moves bf16 (see EXPERIMENTS.md §Methodology)
        coll_bytes = r["collectives"].get(
            "total_wire_bytes_bf16eq", r["collectives"]["total_wire_bytes"] / 2
        )
        t_coll = coll_bytes / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        step = max(terms.values())
        # roofline fraction: useful model flops vs what the step time allows
        model_flops_per_dev = cost.model_flops / n_dev
        frac = (model_flops_per_dev / PEAK_FLOPS) / max(step, 1e-12)
        rec = dict(r)
        rec.update(
            analytic_flops_per_dev=cost.flops,
            analytic_hbm_bytes=cost.hbm_bytes,
            model_flops_global=cost.model_flops,
            useful_ratio=cost.model_flops / max(cost.flops * n_dev, 1.0),
            t_compute_s=t_comp,
            t_memory_s=t_mem,
            t_collective_s=t_coll,
            dominant=dominant,
            est_step_s=step,
            roofline_fraction=frac,
        )
        out.append(rec)
    return out


def to_markdown(rows: List[Dict], mesh: str = "single") -> str:
    hdr = (
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
        "| useful ratio | roofline frac |\n|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            if r.get("mesh") == mesh:
                lines.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | skipped: "
                    f"{r.get('reason','')[:40]} | — | — |"
                )
            continue
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        lines.append(
            "| {arch} | {shape} | {tc:.2f} | {tm:.2f} | {tl:.2f} | {dom} "
            "| {ur:.2f} | {rf:.1%} |".format(
                arch=r["arch"],
                shape=r["shape"],
                tc=r["t_compute_s"] * 1e3,
                tm=r["t_memory_s"] * 1e3,
                tl=r["t_collective_s"] * 1e3,
                dom=r["dominant"],
                ur=r["useful_ratio"],
                rf=r["roofline_fraction"],
            )
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--out", default="roofline_table.json")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    records = json.load(open(args.json))
    rows = analyze(records)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(to_markdown(rows, args.mesh))
    ok = [r for r in rows if r.get("status") == "ok" and r["mesh"] == args.mesh]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        collb = max(ok, key=lambda r: r["t_collective_s"] / max(r["est_step_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_fraction']:.1%})")
        print(f"most collective-bound:  {collb['arch']} x {collb['shape']} "
              f"(t_coll {collb['t_collective_s']*1e3:.1f} ms, dominant={collb['dominant']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
